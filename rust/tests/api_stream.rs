//! Tests for the unified streaming inference API: event ordering,
//! cancellation returning pages to the pool, bounded-admission
//! rejection, byte-identical output between the event path and the
//! legacy `run_to_completion` shim, the scheduler semantics (deadline
//! expiry, fair-share priority admission, cluster-level QueueFull,
//! 1-shard cluster ≡ LocalSession), the shared prefix cache (hit-path
//! bit-exactness, page-boundary admission headroom, drained-cluster
//! refcount-leak checks), multi-turn chat sessions (3-turn chat ≡ cold
//! concatenated-history replay, generated-token donation accounting,
//! eviction pin-leak regression, session-affinity routing on a 2-shard
//! cluster, `chat`/`flush-prefix` wire commands), the v2 TCP
//! event-frame protocol (interleaving, cancel, live stats, raw v1
//! compatibility), and request-lifecycle tracing (the traced span
//! sequence must mirror the `GenerationEvent` stream).
//!
//! Like `integration.rs`, every test needs `make artifacts` and skips
//! with a notice when they are absent.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use quarot::api::{FinishReason, GenerationEvent, GenerationParams, Priority,
                  LocalSession, QualityTier, RequestHandle, SessionConfig,
                  SubmitError};
use quarot::bench_support::{drain_event_signatures, Artifacts};
use quarot::cluster::{ClusterConfig, ClusterService, EngineFactory};
use quarot::coordinator::batcher::{GenerationEngine, Request, TOKENS_PER_PAGE};
use quarot::coordinator::runner::QuantSpec;
use quarot::coordinator::sampler::Sampling;
use quarot::server::{serve, serve_sharded, Client};
use quarot::util::json;

fn art() -> Option<Artifacts> {
    match Artifacts::load("tiny-mha") {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            None
        }
    }
}

fn session(art: &Artifacts, pages: usize, seed: u64, queue_bound: usize)
           -> LocalSession {
    let runner = art.runner(QuantSpec::quarot(4), None).unwrap();
    LocalSession::new(GenerationEngine::new(runner, pages, seed),
                      SessionConfig { queue_bound })
}

#[test]
fn event_stream_is_ordered_with_one_terminal() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..8].to_vec();
    let s = session(&art, 512, 7, 16);
    let h = s.submit(GenerationParams::new(prompt).max_new(6)).unwrap();

    let mut events = Vec::new();
    while let Some(ev) = h.next_event().unwrap() {
        events.push(ev);
    }
    // exact shape: Queued, Started, Token ×6 (contiguous indices), Finished
    assert!(matches!(events[0], GenerationEvent::Queued), "{events:?}");
    assert!(matches!(events[1], GenerationEvent::Started { .. }), "{events:?}");
    let tokens: Vec<(u16, usize)> = events.iter().filter_map(|e| match e {
        GenerationEvent::Token { token, index } => Some((*token, *index)),
        _ => None,
    }).collect();
    assert_eq!(tokens.len(), 6);
    for (i, &(_, idx)) in tokens.iter().enumerate() {
        assert_eq!(idx, i, "token indices must be contiguous from 0");
    }
    let terminals: Vec<&GenerationEvent> =
        events.iter().filter(|e| e.is_terminal()).collect();
    assert_eq!(terminals.len(), 1, "exactly one terminal event");
    match terminals[0] {
        GenerationEvent::Finished { reason, stats } => {
            assert_eq!(*reason, FinishReason::MaxTokens);
            assert_eq!(stats.generated, 6);
            assert_eq!(stats.prompt_len, 8);
        }
        other => panic!("wrong terminal {other:?}"),
    }
    assert!(events.last().unwrap().is_terminal(),
            "terminal must come last: {events:?}");
    // a drained handle stays drained
    assert!(h.next_event().unwrap().is_none());
}

#[test]
fn cancellation_frees_pool_pages() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..8].to_vec();
    let s = session(&art, 512, 7, 16);
    assert_eq!(s.pool_in_use(), 0);

    let h = s.submit(GenerationParams::new(prompt).max_new(64)).unwrap();
    // stream a few tokens so the request is mid-flight with pages held
    let mut seen_tokens = 0;
    while seen_tokens < 3 {
        match h.next_event().unwrap().expect("stream ended early") {
            GenerationEvent::Token { .. } => seen_tokens += 1,
            e => assert!(!e.is_terminal(), "finished before cancel: {e:?}"),
        }
    }
    assert!(s.pool_in_use() > 0, "mid-flight request must hold pages");
    assert!(h.cancel().unwrap());
    assert_eq!(s.pool_in_use(), 0,
               "cancel must return every page to the pool");

    // the stream still terminates in exactly one Finished{Cancelled}
    let mut terminals = 0;
    while let Some(ev) = h.next_event().unwrap() {
        if let GenerationEvent::Finished { reason, .. } = &ev {
            assert_eq!(*reason, FinishReason::Cancelled);
            terminals += 1;
        } else {
            assert!(!ev.is_terminal());
        }
    }
    assert_eq!(terminals, 1);
    // cancelling again is a no-op
    assert!(!h.cancel().unwrap());
}

#[test]
fn queue_full_rejection_at_the_bound() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..4].to_vec();
    let s = session(&art, 512, 7, 2);

    let h1 = s.submit(GenerationParams::new(prompt.clone()).max_new(3)).unwrap();
    let h2 = s.submit(GenerationParams::new(prompt.clone()).max_new(3)).unwrap();
    // third submit exceeds the bound of 2 waiting requests
    match s.submit(GenerationParams::new(prompt.clone()).max_new(3)) {
        Err(SubmitError::QueueFull { bound }) => assert_eq!(bound, 2),
        Err(e) => panic!("expected QueueFull, got {e:?}"),
        Ok(_) => panic!("expected QueueFull, got an accepted request"),
    }
    // draining the queue frees admission capacity again
    h1.wait().unwrap();
    h2.wait().unwrap();
    let h3 = s.submit(GenerationParams::new(prompt).max_new(3)).unwrap();
    assert_eq!(h3.wait().unwrap().tokens.len(), 3);
}

#[test]
fn invalid_params_are_typed_rejections() {
    let Some(art) = art() else { return };
    let s = session(&art, 512, 7, 16);
    assert!(matches!(s.submit(GenerationParams::new(vec![])),
                     Err(SubmitError::InvalidParams(_))));
    assert!(matches!(s.submit(GenerationParams::new(vec![1]).max_new(0)),
                     Err(SubmitError::InvalidParams(_))));
    let too_long = vec![1u16; 100_000];
    assert!(matches!(s.submit(GenerationParams::new(too_long)),
                     Err(SubmitError::InvalidParams(_))));
}

#[test]
fn event_path_matches_legacy_shim_byte_identical() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[20..30].to_vec();
    let sampling = Sampling::TopK { temperature: 0.8, k: 8 };

    // legacy path: run_to_completion shim at a fixed seed
    let runner = art.runner(QuantSpec::quarot(4), None).unwrap();
    let mut engine = GenerationEngine::new(runner, 512, 11);
    engine.submit(Request {
        id: 0, prompt: prompt.clone(), max_new_tokens: 8,
        sampling, stop_token: None,
        priority: Priority::Interactive, deadline_ms: None,
        tier: QualityTier::Kv4, session: None,
    });
    let legacy = engine.run_to_completion().unwrap();
    assert_eq!(legacy.len(), 1);
    assert_eq!(legacy[0].tokens.len(), 8);

    // event path: same seed, same request, fresh engine
    let runner = art.runner(QuantSpec::quarot(4), None).unwrap();
    let s = LocalSession::new(GenerationEngine::new(runner, 512, 11),
                              SessionConfig::default());
    let h = s.submit(GenerationParams::new(prompt).max_new(8)
                         .sampling(sampling)).unwrap();
    let streamed = h.wait().unwrap();

    assert_eq!(legacy[0].tokens, streamed.tokens,
               "event path must be byte-identical to the shim");
}

#[test]
fn stop_token_on_first_prefill_token_retires_immediately() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..8].to_vec();
    // learn what the first greedy token is
    let s = session(&art, 512, 7, 16);
    let probe = s.submit(GenerationParams::new(prompt.clone()).max_new(2))
        .unwrap().wait().unwrap();
    let first = probe.tokens[0];

    // resubmit with that token as the stop token: the request must
    // finish at admission with reason Stop, never occupying a slot
    let s = session(&art, 512, 7, 16);
    let h = s.submit(GenerationParams::new(prompt).max_new(32).stop_at(first))
        .unwrap();
    let out = h.wait().unwrap();
    assert_eq!(out.tokens, vec![first]);
    assert_eq!(out.reason, FinishReason::Stop);
    assert_eq!(s.pool_in_use(), 0, "admission-time stop must free pages");
    let stats = s.stats();
    assert_eq!(stats.decode_steps, 0,
               "a first-token stop must not run decode ticks");
}

#[test]
fn deadline_exceeded_mid_stream_frees_pages() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..8].to_vec();
    let s = session(&art, 512, 7, 16);
    // generous budget, tight deadline: the tick must retire it mid-stream
    let h = s.submit(GenerationParams::new(prompt).max_new(400).deadline(40))
        .unwrap();
    let mut tokens = 0usize;
    let mut reason = None;
    let mut terminals = 0usize;
    while let Some(ev) = h.next_event().unwrap() {
        match ev {
            GenerationEvent::Token { .. } => {
                tokens += 1;
                if tokens == 2 {
                    // let the deadline lapse while the request is active
                    std::thread::sleep(std::time::Duration::from_millis(60));
                }
            }
            GenerationEvent::Finished { reason: r, .. } => {
                terminals += 1;
                reason = Some(r);
            }
            GenerationEvent::Failed { .. } => terminals += 1,
            _ => {}
        }
    }
    assert_eq!(terminals, 1, "exactly one terminal event");
    assert_eq!(reason, Some(FinishReason::DeadlineExceeded));
    assert!(tokens < 400, "deadline must land mid-generation");
    assert_eq!(s.pool_in_use(), 0,
               "deadline retirement must return every KV page to the pool");
}

#[test]
fn deadline_expired_in_queue_never_admits() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..8].to_vec();
    let s = session(&art, 512, 7, 16);
    // deadline 0 = expired on arrival: retired from the queue at the next
    // tick, before prefill ever runs
    let h = s.submit(GenerationParams::new(prompt).max_new(8).deadline(0))
        .unwrap();
    let out = h.wait().unwrap();
    assert_eq!(out.reason, FinishReason::DeadlineExceeded);
    assert!(out.tokens.is_empty(), "expired-in-queue must produce no tokens");
    assert_eq!(out.stats.generated, 0);
    assert_eq!(s.pool_in_use(), 0);
    let stats = s.stats();
    assert_eq!(stats.decode_steps, 0, "no decode tick for an expired request");
    assert_eq!(stats.deadline_exceeded, 1);
}

#[test]
fn interactive_admitted_ahead_of_queued_batch_backlog() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..6].to_vec();
    let s = session(&art, 1024, 7, 64);
    // a Batch backlog queued before any tick runs...
    let mut batch_ids = Vec::new();
    for _ in 0..6 {
        batch_ids.push(s.submit_detached(
            GenerationParams::new(prompt.clone()).max_new(24)
                .priority(Priority::Batch)).unwrap());
    }
    // ...then one Interactive arrival, submitted last
    let inter_id = s.submit_detached(
        GenerationParams::new(prompt.clone()).max_new(4)).unwrap();

    // multiplexed consumption: drive ticks and record global event order
    let mut first_started = None;
    let mut terminals = 0usize;
    while terminals < 7 {
        for (id, ev) in s.poll_events() {
            match ev {
                GenerationEvent::Started { .. } => {
                    first_started.get_or_insert(id);
                }
                e if e.is_terminal() => terminals += 1,
                _ => {}
            }
        }
    }
    // the weighted-deficit scheduler admits the interactive request in
    // the very first admission wave, ahead of the whole batch backlog
    assert_eq!(first_started, Some(inter_id),
               "interactive must start before any queued batch request");
}

/// Acceptance: a 1-shard cluster is behaviorally identical to a
/// LocalSession — same per-request event streams for the same seeded
/// greedy requests (timing fields excluded; tick scheduling differs by
/// design, which greedy decoding is invariant to).
#[test]
fn one_shard_cluster_matches_local_session() {
    let Some(art) = art() else { return };
    let eval = art.corpus.split("eval").unwrap();
    let prompts: Vec<Vec<u16>> = (0..3)
        .map(|i| eval[i * 31..i * 31 + 8].to_vec())
        .collect();

    let s = session(&art, 512, 9, 16);
    let hs: Vec<RequestHandle> = prompts.iter()
        .map(|p| s.submit(GenerationParams::new(p.clone()).max_new(6)).unwrap())
        .collect();
    let local = drain_event_signatures(&hs).unwrap();

    let factory: EngineFactory = Arc::new(|| {
        let art = Artifacts::load("tiny-mha")?;
        let runner = art.runner(QuantSpec::quarot(4), None)?;
        Ok(GenerationEngine::new(runner, 512, 9))
    });
    let c = ClusterService::new(factory,
                                ClusterConfig { shards: 1, queue_bound: 16 });
    let hc: Vec<RequestHandle> = prompts.iter()
        .map(|p| c.submit(GenerationParams::new(p.clone()).max_new(6)).unwrap())
        .collect();
    let clustered = drain_event_signatures(&hc).unwrap();

    assert_eq!(local, clustered,
               "1-shard cluster must mirror LocalSession event streams");
}

#[test]
fn cluster_queue_full_only_when_every_shard_is_bound() {
    let Some(art) = art() else { return };
    // slot capacity per shard = the model's decode batch width
    let b = art.runner(QuantSpec::quarot(4), None).unwrap().cfg.decode_batch;
    let factory: EngineFactory = Arc::new(|| {
        let art = Artifacts::load("tiny-mha")?;
        let runner = art.runner(QuantSpec::quarot(4), None)?;
        Ok(GenerationEngine::new(runner, 2048, 7))
    });
    let cluster = ClusterService::new(factory,
                                      ClusterConfig { shards: 2, queue_bound: 1 });
    let prompt = art.corpus.split("eval").unwrap()[..4].to_vec();
    // long-running: occupies its slot for the whole test
    let long = || GenerationParams::new(prompt.clone()).max_new(100_000);

    // fill every slot on both shards, waiting for each admission so the
    // queues stay empty during the fill (placement stays deterministic)
    let mut handles: Vec<RequestHandle> = Vec::new();
    for _ in 0..2 * b {
        let h = cluster.submit(long()).unwrap();
        let t0 = std::time::Instant::now();
        while cluster.metrics().queue_depth() > 0 {
            assert!(t0.elapsed().as_secs() < 30, "admission stalled");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        handles.push(h);
    }
    // one queued request per shard reaches each bound of 1
    for _ in 0..2 {
        handles.push(cluster.submit(long()).unwrap());
    }
    // now every shard is saturated: the cluster-level backpressure signal
    match cluster.submit(long()) {
        Err(SubmitError::QueueFull { bound }) => {
            assert_eq!(bound, 2, "cluster bound = per-shard bound × shards");
        }
        Err(e) => panic!("expected cluster QueueFull, got {e:?}"),
        Ok(h) => panic!("expected cluster QueueFull, got accepted id {}", h.id()),
    }

    // cancelling everything drains both pools and reopens admission
    for h in &handles {
        h.cancel().unwrap();
    }
    for h in &handles {
        while h.next_event().unwrap().is_some() {}
    }
    let m = cluster.metrics();
    assert_eq!(m.pool_pages_in_use(), 0, "cancel must drain every shard pool");
    assert!(m.cancelled() >= 1, "cancellations must be counted: {m:?}");
    let h = cluster.submit(GenerationParams::new(prompt.clone()).max_new(2))
        .unwrap();
    assert_eq!(h.wait().unwrap().tokens.len(), 2,
               "admission must reopen after the backlog drains");
}

/// Session with an explicit prefix-cache page budget (0 disables).
fn session_with_prefix(art: &Artifacts, pages: usize, seed: u64,
                       prefix_pages: usize) -> LocalSession {
    let runner = art.runner(QuantSpec::quarot(4), None).unwrap();
    let mut engine = GenerationEngine::new(runner, pages, seed);
    engine.set_prefix_cache_pages(prefix_pages);
    LocalSession::new(engine, SessionConfig::default())
}

/// Acceptance: generations through the prefix cache — full hit, partial
/// hit with CoW divergence, and miss — are byte-identical to cold-path
/// generations at the same seed, and the only pages held after the
/// sessions drain are the trie's own (released by a flush: no refcount
/// leaks).
#[test]
fn prefix_cache_hit_path_is_byte_identical_and_leak_free() {
    let Some(art) = art() else { return };
    let eval = art.corpus.split("eval").unwrap();
    let tpp = TOKENS_PER_PAGE;
    if eval.len() < 16 * tpp {
        eprintln!("[skip] eval split too short for prefix-cache prompts");
        return;
    }
    // P0: donor.  P1: shares P0's first two pages, diverges after (CoW).
    // P2 = P0 (full-prefix hit).  P3: disjoint (miss).
    let p0: Vec<u16> = eval[..2 * tpp + 8].to_vec();
    let mut p1 = eval[..2 * tpp].to_vec();
    p1.extend_from_slice(&eval[7 * tpp..7 * tpp + 8]);
    let p2 = p0.clone();
    let p3: Vec<u16> = eval[10 * tpp..12 * tpp + 8].to_vec();
    let prompts = [p0, p1, p2, p3];

    let run = |prefix_pages: usize| -> (Vec<Vec<u16>>, LocalSession) {
        let s = session_with_prefix(&art, 2048, 17, prefix_pages);
        let tokens = prompts.iter()
            .map(|p| {
                s.submit(GenerationParams::new(p.clone()).max_new(6))
                    .unwrap().wait().unwrap().tokens
            })
            .collect();
        (tokens, s)
    };
    let (cold, _cold_s) = run(0);
    let (hot, hot_s) = run(1024);
    assert_eq!(cold, hot,
               "prefix-cache generations must be byte-identical to cold");

    let ps = hot_s.prefix_stats();
    assert_eq!(ps.lookups, 4);
    assert_eq!(ps.hits, 2, "P1 (partial) and P2 (full) must hit: {ps:?}");
    assert_eq!(ps.hit_tokens, 2 * 2 * tpp,
               "both hits graft two full pages");
    // drained session: only the trie's donated pages remain pinned...
    assert_eq!(hot_s.pool_in_use(), ps.pages_pinned,
               "drained session must hold exactly the trie's pages");
    assert!(ps.pages_pinned > 0, "cold prefills must donate");
    // ...and a flush returns every last page (no refcount leaks)
    hot_s.clear_prefix_cache();
    assert_eq!(hot_s.pool_in_use(), 0, "prefix flush must drain the pool");
}

/// Satellite regression: a prompt that exactly fills its pages must not
/// admit into a pool with zero decode headroom and then die on its
/// first append with a spurious `KV append failed` — the admission
/// estimate reserves one decode token, so the request fails fast with a
/// typed page-admission error (or waits, when the pool is merely busy).
#[test]
fn admission_reserves_decode_headroom_at_page_boundary() {
    let Some(art) = art() else { return };
    let eval = art.corpus.split("eval").unwrap();
    let tpp = TOKENS_PER_PAGE;
    let l = art.runner(QuantSpec::quarot(4), None).unwrap().cfg.n_layers;
    let prompt: Vec<u16> = eval[..2 * tpp].to_vec(); // exactly 2 pages

    // pool = exactly the prompt's pages → can never also hold the first
    // decode append: typed fail-fast, before any prefill or decode
    let s = session_with_prefix(&art, 2 * l * 2, 3, 0);
    let h = s.submit(GenerationParams::new(prompt.clone()).max_new(4)).unwrap();
    let err = h.wait().unwrap_err().to_string();
    assert!(err.contains("KV pages"),
            "expected the typed page-admission failure, got: {err}");
    assert!(!err.contains("KV append failed"),
            "spurious first-append failure is the old bug: {err}");
    assert_eq!(s.stats().decode_steps, 0, "must fail before any decode");
    assert_eq!(s.pool_in_use(), 0);

    // one more page row of headroom: the same request completes
    let s = session_with_prefix(&art, 2 * l * 3, 3, 0);
    let h = s.submit(GenerationParams::new(prompt).max_new(4)).unwrap();
    assert_eq!(h.wait().unwrap().tokens.len(), 4);
    assert_eq!(s.pool_in_use(), 0);
}

/// Acceptance: a fully-drained cluster holds only its prefix tries'
/// pages, affinity routing funnels shared-prefix traffic into cache
/// hits, and flushing the tries returns every shard's pool to zero.
#[test]
fn drained_cluster_pools_drain_to_zero_after_prefix_clear() {
    let Some(art) = art() else { return };
    let eval = art.corpus.split("eval").unwrap();
    let tpp = TOKENS_PER_PAGE;
    if eval.len() < 16 * tpp {
        eprintln!("[skip] eval split too short for prefix-cache prompts");
        return;
    }
    let factory: EngineFactory = Arc::new(|| {
        let art = Artifacts::load("tiny-mha")?;
        let runner = art.runner(QuantSpec::quarot(4), None)?;
        Ok(GenerationEngine::new(runner, 2048, 5))
    });
    let cluster = ClusterService::new(factory,
                                      ClusterConfig { shards: 2, queue_bound: 64 });
    // shared-prefix traffic: one common 2-page system prompt, unique tails
    let base: Vec<u16> = eval[..2 * tpp].to_vec();
    let handles: Vec<RequestHandle> = (0..6)
        .map(|i| {
            let mut p = base.clone();
            p.extend_from_slice(&eval[4 * tpp + i * 8..4 * tpp + i * 8 + 8]);
            cluster.submit(GenerationParams::new(p).max_new(4)).unwrap()
        })
        .collect();
    for h in &handles {
        h.wait().unwrap();
    }
    let m = cluster.metrics();
    assert_eq!(m.pool_pages_in_use(), m.prefix_pages_pinned(),
               "drained cluster must hold only prefix-cache pages");
    assert!(m.prefix_pages_pinned() > 0, "cold prefills must donate");
    assert!(m.prefix_hits() >= 1,
            "affinity-routed shared-prefix traffic must hit the cache");
    cluster.clear_prefix_caches();
    let m = cluster.metrics();
    assert_eq!(m.pool_pages_in_use(), 0,
               "flushed cluster must return every shard's pool to zero");
}

/// Acceptance: a 3-turn chat session is token-for-token identical to
/// cold resubmission of the concatenated history, and the donation
/// gauge counts exactly the pages of history each resumed turn grafts
/// from the trie instead of re-prefilling.
#[test]
fn chat_session_matches_cold_replay_and_counts_donated_prefill() {
    let Some(art) = art() else { return };
    let eval = art.corpus.split("eval").unwrap();
    let tpp = TOKENS_PER_PAGE;
    if eval.len() < 16 * tpp {
        eprintln!("[skip] eval split too short for chat prompts");
        return;
    }
    let max_new = 8usize;
    let turns: [Vec<u16>; 3] = [
        eval[..tpp].to_vec(),
        eval[12 * tpp..12 * tpp + 8].to_vec(),
        eval[14 * tpp..14 * tpp + 8].to_vec(),
    ];

    // chat path: one session, three turns, server-side history
    let s = session_with_prefix(&art, 2048, 11, 1024);
    let out1 = s.submit(GenerationParams::new(turns[0].clone())
            .max_new(max_new).new_session()).unwrap().wait().unwrap();
    let sid = out1.stats.session.expect("a New session must learn its id");
    let out2 = s.submit(GenerationParams::new(turns[1].clone())
            .max_new(max_new).resume_session(sid)).unwrap().wait().unwrap();
    let out3 = s.submit(GenerationParams::new(turns[2].clone())
            .max_new(max_new).resume_session(sid)).unwrap().wait().unwrap();
    assert_eq!(out2.stats.session, Some(sid));
    assert_eq!(out3.stats.session, Some(sid));
    // the engine, not the caller, threads the conversation history
    let h2 = turns[0].len() + max_new + turns[1].len();
    assert_eq!(out2.stats.prompt_len, h2,
               "turn 2 must prefill over the stored turn-1 chain");
    let h3 = h2 + max_new + turns[2].len();
    assert_eq!(out3.stats.prompt_len, h3);

    // replay path: a cold engine (same seed, prefix cache off) fed the
    // concatenated history must emit the same tokens, turn for turn
    let cold = session_with_prefix(&art, 2048, 11, 0);
    let c1 = cold.submit(GenerationParams::new(turns[0].clone())
            .max_new(max_new)).unwrap().wait().unwrap();
    assert_eq!(c1.tokens, out1.tokens, "turn 1 must match cold");
    let mut hist: Vec<u16> = turns[0].clone();
    hist.extend_from_slice(&c1.tokens);
    hist.extend_from_slice(&turns[1]);
    let c2 = cold.submit(GenerationParams::new(hist.clone())
            .max_new(max_new)).unwrap().wait().unwrap();
    assert_eq!(c2.tokens, out2.tokens, "turn 2 must match cold replay");
    hist.extend_from_slice(&c2.tokens);
    hist.extend_from_slice(&turns[2]);
    let c3 = cold.submit(GenerationParams::new(hist)
            .max_new(max_new)).unwrap().wait().unwrap();
    assert_eq!(c3.tokens, out3.tokens, "turn 3 must match cold replay");

    // donation accounting: each resumed turn grafts every full page of
    // its history — the page holding a turn's final sampled token never
    // reaches the KV cache, so the donated chain (and the savings) is
    // the history rounded down to whole pages
    let st = s.stats();
    let saved2 = (turns[0].len() + max_new - 1) / tpp * tpp;
    let saved3 = (h2 + max_new - 1) / tpp * tpp;
    assert_eq!(st.session_prefill_tokens_saved, saved2 + saved3,
               "saved must be ≈ full history length on turns ≥ 2");
    assert_eq!(st.session_turns, 3);
    assert_eq!(s.sessions_live(), 1);
}

/// Satellite regression: evicting a session must release its pinned
/// trie chain — after a budget-shrink eviction and a trie flush, the
/// pinned-page gauge and the pool both return to zero.  A leaked pin
/// trips the flush's pinned-pages debug assertion; a refcount leak
/// strands pool pages past the flush.
#[test]
fn session_eviction_releases_pinned_chain_pages() {
    let Some(art) = art() else { return };
    let eval = art.corpus.split("eval").unwrap();
    let tpp = TOKENS_PER_PAGE;
    if eval.len() < 16 * tpp {
        eprintln!("[skip] eval split too short for chat prompts");
        return;
    }
    let s = session_with_prefix(&art, 2048, 13, 1024);

    // session A: two turns (exercises the pin handover that re-pins the
    // longer chain before unpinning the turn-1 chain)
    let sid_a = s.submit(GenerationParams::new(eval[..tpp].to_vec())
            .max_new(8).new_session()).unwrap()
        .wait().unwrap().stats.session.unwrap();
    s.submit(GenerationParams::new(eval[5 * tpp..5 * tpp + 8].to_vec())
            .max_new(8).resume_session(sid_a)).unwrap().wait().unwrap();
    // session B: one turn on a disjoint prompt
    let out_b = s.submit(GenerationParams::new(eval[8 * tpp..9 * tpp].to_vec())
            .max_new(8).new_session()).unwrap().wait().unwrap();
    assert_ne!(out_b.stats.session, Some(sid_a), "ids must be distinct");
    assert_eq!(s.sessions_live(), 2);
    let ps = s.prefix_stats();
    assert!(ps.pages_pinned > 0, "donated chains must hold trie pages");
    assert_eq!(s.pool_in_use(), ps.pages_pinned,
               "drained sessions must hold only the trie's pages");

    // shrink the budget: the LRU session (A) is evicted and its chain
    // unpinned; the trie still holds the now-unpinned pages...
    s.set_session_budget(1);
    assert_eq!(s.sessions_live(), 1);
    assert!(s.prefix_stats().pages_pinned > 0);

    // ...until the flush, which must return every last page
    s.clear_prefix_cache();
    assert_eq!(s.prefix_stats().pages_pinned, 0,
               "flush after eviction must empty the trie");
    assert_eq!(s.pool_in_use(), 0, "no pages may leak past the flush");
}

/// Session-affinity routing: on a 2-shard cluster every resumed turn
/// must land on the shard that owns the session's history and donated
/// chain.  A turn routed to the wrong shard re-registers cold with an
/// empty history, so its effective prompt — and therefore its greedy
/// reply — would diverge from the single-engine chat.
#[test]
fn cluster_routes_resumed_turns_to_the_owning_shard() {
    let Some(art) = art() else { return };
    let eval = art.corpus.split("eval").unwrap();
    let tpp = TOKENS_PER_PAGE;
    if eval.len() < 16 * tpp {
        eprintln!("[skip] eval split too short for chat prompts");
        return;
    }
    let max_new = 8usize;
    let turns: [Vec<u16>; 3] = [
        eval[..tpp].to_vec(),
        eval[12 * tpp..12 * tpp + 8].to_vec(),
        eval[14 * tpp..14 * tpp + 8].to_vec(),
    ];
    let params = |sid: Option<u64>, t: &[u16]| {
        let p = GenerationParams::new(t.to_vec()).max_new(max_new);
        match sid {
            None => p.new_session(),
            Some(id) => p.resume_session(id),
        }
    };

    // reference: the same three turns on a single engine
    let s = session(&art, 2048, 9, 16);
    let l1 = s.submit(params(None, &turns[0])).unwrap().wait().unwrap();
    let lsid = l1.stats.session.expect("New must assign an id");
    let l2 = s.submit(params(Some(lsid), &turns[1])).unwrap().wait().unwrap();
    let l3 = s.submit(params(Some(lsid), &turns[2])).unwrap().wait().unwrap();

    let factory: EngineFactory = Arc::new(|| {
        let art = Artifacts::load("tiny-mha")?;
        let runner = art.runner(QuantSpec::quarot(4), None)?;
        Ok(GenerationEngine::new(runner, 2048, 9))
    });
    let c = ClusterService::new(factory,
                                ClusterConfig { shards: 2, queue_bound: 16 });
    let c1 = c.submit(params(None, &turns[0])).unwrap().wait().unwrap();
    let sid = c1.stats.session.expect("New must assign an id");
    let c2 = c.submit(params(Some(sid), &turns[1])).unwrap().wait().unwrap();
    let c3 = c.submit(params(Some(sid), &turns[2])).unwrap().wait().unwrap();
    assert_eq!(c2.stats.session, Some(sid));
    assert_eq!(c3.stats.session, Some(sid));
    assert_eq!([&l1.tokens, &l2.tokens, &l3.tokens],
               [&c1.tokens, &c2.tokens, &c3.tokens],
               "session-affine routing must keep the history on one shard");

    // exactly one shard owns the session, and its donation gauge shows
    // the same savings a single engine accrues
    let m = c.metrics();
    assert_eq!(m.sessions_live(), 1);
    assert_eq!(m.session_turns(), 3);
    let h2 = turns[0].len() + max_new + turns[1].len();
    let expect_saved = (turns[0].len() + max_new - 1) / tpp * tpp
        + (h2 + max_new - 1) / tpp * tpp;
    assert_eq!(m.session_prefill_tokens_saved(), expect_saved,
               "resumed turns must hit the owner's donated chain");
}

/// The wire path: `chat` frames assign and resume sessions over TCP,
/// the session gauges surface on the stats frame, and `flush-prefix`
/// round-trips an ack and returns every trie page to the pool.
#[test]
fn tcp_chat_resumes_sessions_and_flush_prefix_acks() {
    if art().is_none() {
        return;
    }
    let handle = serve(
        move || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 2048, 3))
        },
        0,
        16,
    ).unwrap();

    let client = Client::connect(handle.port).unwrap();
    let t1: Vec<u16> = (0..16).map(|i| 5 + i as u16).collect();
    let out1 = client.chat(None, &GenerationParams::new(t1.clone()).max_new(8))
        .unwrap().wait().unwrap();
    let sid = out1.stats.session.expect("chat must assign a session id");
    let out2 = client
        .chat(Some(sid), &GenerationParams::new(vec![40, 41, 42, 43]).max_new(8))
        .unwrap().wait().unwrap();
    assert_eq!(out2.stats.session, Some(sid), "a resumed turn keeps its id");
    assert_eq!(out2.stats.prompt_len, t1.len() + 8 + 4,
               "the server must prepend the stored history");

    let mut c2 = Client::connect(handle.port).unwrap();
    let stats = c2.stats().unwrap();
    assert_eq!(stats.get("sessions_live").unwrap().as_usize(), Some(1));
    assert_eq!(stats.get("session_turns").unwrap().as_usize(), Some(2));
    let saved = stats.get("session_prefill_tokens_saved").unwrap()
        .as_usize().unwrap();
    assert!(saved >= TOKENS_PER_PAGE,
            "the resumed turn must be served from the donated chain");

    // flush-prefix: acked, and every trie page returns to the pool
    c2.flush_prefix().unwrap();
    let stats = c2.stats().unwrap();
    assert_eq!(stats.get("prefix_pages_pinned").unwrap().as_f64(), Some(0.0));
    assert_eq!(stats.get("pool_pages_in_use").unwrap().as_f64(), Some(0.0));
    handle.shutdown();
}

#[test]
fn tcp_interleaved_requests_and_cancel() {
    if art().is_none() {
        return;
    }
    let handle = serve(
        move || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 512, 3))
        },
        0,
        16,
    ).unwrap();

    let client = Client::connect(handle.port).unwrap();
    let ha = client.submit(&GenerationParams::new(vec![5, 6, 7, 8]).max_new(12))
        .unwrap();
    // B gets a budget ~200 ticks long and is cancelled at its first token
    // frame, so the cancel cannot lose the race to natural completion
    let hb = client.submit(&GenerationParams::new(vec![9, 10, 11, 12]).max_new(200))
        .unwrap();
    assert_ne!(ha.id(), hb.id());

    // pull B's frames; cancel it as soon as it streams
    let mut b_tokens = 0;
    let mut b_reason = None;
    let mut b_terminals = 0;
    while let Some(ev) = hb.next_event().unwrap() {
        match ev {
            GenerationEvent::Token { .. } => {
                b_tokens += 1;
                if b_tokens == 1 {
                    hb.cancel().unwrap();
                }
            }
            GenerationEvent::Finished { reason, .. } => {
                b_terminals += 1;
                b_reason = Some(reason);
            }
            GenerationEvent::Failed { .. } => b_terminals += 1,
            _ => {}
        }
    }
    assert_eq!(b_terminals, 1, "exactly one terminal event for B");
    assert_eq!(b_reason, Some(FinishReason::Cancelled));
    assert!(b_tokens < 200, "cancel must land mid-generation");

    // A is untouched: full budget, single natural terminal
    let out_a = ha.wait().unwrap();
    assert_eq!(out_a.tokens.len(), 12);
    assert_eq!(out_a.reason, FinishReason::MaxTokens);

    // cancelled pages are back in the pool (server-side accounting)
    let mut c2 = Client::connect(handle.port).unwrap();
    let stats = c2.stats().unwrap();
    assert_eq!(stats.get("pool_pages_in_use").unwrap().as_f64().unwrap(), 0.0);
    assert!(stats.get("cancelled").unwrap().as_f64().unwrap() >= 1.0);
    handle.shutdown();
}

#[test]
fn raw_v1_one_shot_line_still_answered() {
    if art().is_none() {
        return;
    }
    let handle = serve(
        move || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 512, 3))
        },
        0,
        16,
    ).unwrap();

    // speak v1 by hand: one bare JSON line in, one completion object out
    let stream = TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(w, r#"{{"prompt":[5,6,7,8],"max_new_tokens":4}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = json::parse(line.trim()).unwrap();
    assert!(resp.get("error").is_none(), "{resp:?}");
    assert_eq!(resp.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert!(resp.get("tokens_per_sec").is_some());
    // regression: the v1 one-shot reply must stay a bare completion
    // object — no v2 frame envelope, no cluster fields
    assert!(resp.get("v").is_none(), "v1 reply grew a version tag: {resp:?}");
    assert!(resp.get("event").is_none(),
            "v1 reply grew an event discriminator: {resp:?}");
    assert!(resp.get("finish_reason").is_some());
    handle.shutdown();
}

#[test]
fn stats_frame_reports_live_load_and_metrics_break_out_shards() {
    if art().is_none() {
        return;
    }
    let handle = serve_sharded(
        || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 512, 3))
        },
        0,
        16,
        2,
    ).unwrap();

    let client = Client::connect(handle.port).unwrap();
    // park a backlog of long-running requests so the gauges have
    // something to show while the stats round-trip happens
    let handles: Vec<_> = (0..6)
        .map(|_| client.submit(&GenerationParams::new(vec![5, 6, 7, 8])
                                   .max_new(100_000)).unwrap())
        .collect();

    let mut c2 = Client::connect(handle.port).unwrap();
    let stats = c2.stats().unwrap();
    for key in ["queue_depth", "active_slots", "shards", "deadline_exceeded",
                "completed", "pool_pages_in_use", "queue_bound",
                "prefix_lookups", "prefix_hit_rate", "prefix_tokens_saved",
                "prefix_pages_pinned"] {
        assert!(stats.get(key).is_some(), "stats frame missing {key}: {stats:?}");
    }
    assert_eq!(stats.get("shards").unwrap().as_usize(), Some(2));
    let live = stats.get("queue_depth").unwrap().as_usize().unwrap()
        + stats.get("active_slots").unwrap().as_usize().unwrap();
    assert!(live >= 1, "an in-flight request must show up in the live load");

    // the metrics command adds the per-shard breakdown
    let metrics = c2.metrics().unwrap();
    let per_shard = metrics.get("per_shard").and_then(|p| p.as_arr()).unwrap();
    assert_eq!(per_shard.len(), 2);
    for (i, row) in per_shard.iter().enumerate() {
        assert_eq!(row.get("shard").unwrap().as_usize(), Some(i));
        assert!(row.get("pages_in_use").is_some());
        assert!(row.get("queue_depth").is_some());
        assert!(row.get("prefix_hit_rate").is_some());
        assert!(row.get("prefix_pages_pinned").is_some());
    }

    for h in &handles {
        h.cancel().unwrap();
    }
    for h in &handles {
        while h.next_event().unwrap().is_some() {}
    }
    handle.shutdown();
}

#[test]
fn wire_shutdown_cmd_stops_the_whole_server() {
    if art().is_none() {
        return;
    }
    let handle = serve(
        move || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 512, 3))
        },
        0,
        16,
    ).unwrap();
    let port = handle.port;
    let mut c = Client::connect(port).unwrap();
    c.shutdown_server().unwrap();
    // both loops must exit: join returns (would hang forever before the
    // fix, when shutdown only closed the issuing connection)
    handle.shutdown();
    // and new connections are no longer served
    std::thread::sleep(std::time::Duration::from_millis(50));
    let refused = match TcpStream::connect(("127.0.0.1", port)) {
        Err(_) => true,
        Ok(s) => {
            // listener may linger in TIME_WAIT; a served connection would
            // answer a stats line, a dead one hangs up
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut w = s;
            let _ = writeln!(w, r#"{{"v":2,"cmd":"stats"}}"#);
            let mut line = String::new();
            matches!(r.read_line(&mut line), Ok(0) | Err(_))
        }
    };
    assert!(refused, "server still answering after wire shutdown");
}
#[test]
fn traced_span_sequence_matches_the_event_stream() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..8].to_vec();
    let s = session(&art, 512, 7, 16);
    s.set_trace_buffer(256); // default sampling (1): keep every decode span
    let h = s.submit(GenerationParams::new(prompt.clone()).max_new(6)).unwrap();
    let id = h.id();

    let mut events = Vec::new();
    while let Some(ev) = h.next_event().unwrap() {
        events.push(ev);
    }
    let token_events = events.iter()
        .filter(|e| matches!(e, GenerationEvent::Token { .. }))
        .count();
    assert_eq!(token_events, 6);

    let spans = s.drain_spans();
    // lifecycle spans ride the request's track (= its id); per-tick
    // engine phase spans ride track 0
    let lifecycle: Vec<&str> = spans.iter()
        .filter(|sp| sp.track == id)
        .map(|sp| sp.name)
        .collect();
    // record order mirrors the event stream: `queued` at submit,
    // `prefill` + `admitted` during the admission tick (which also
    // emits Started and the index-0 Token), one `decode_token` per
    // subsequent Token event, and the finish marker last
    let mut want = vec!["queued", "prefill", "admitted"];
    want.extend(std::iter::repeat("decode_token").take(token_events - 1));
    want.push("finish:max_tokens");
    assert_eq!(lifecycle, want,
               "traced span sequence must mirror the event stream");

    // decode spans carry the token index: contiguous 1..N, matching the
    // Token events that followed the admission token
    let decode_idx: Vec<f64> = spans.iter()
        .filter(|sp| sp.track == id && sp.name == "decode_token")
        .map(|sp| sp.args[0].1)
        .collect();
    let want_idx: Vec<f64> = (1..token_events).map(|i| i as f64).collect();
    assert_eq!(decode_idx, want_idx);

    // the tick phases that produced those tokens were traced too
    assert!(spans.iter().any(|sp| sp.track == 0 && sp.name == "tick.decode"),
            "engine phase spans missing from the ring");

    // draining emptied the ring
    assert!(s.drain_spans().is_empty());

    // disabling the recorder stops recording entirely
    s.set_trace_buffer(0);
    let h2 = s.submit(GenerationParams::new(prompt).max_new(3)).unwrap();
    h2.wait().unwrap();
    assert!(s.drain_spans().is_empty(),
            "a disabled recorder must record nothing");
}
