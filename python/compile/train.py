"""Train the synthetic checkpoints (build-time only; DESIGN.md §1).

Plain Adam + cross-entropy on the bigram-mixture corpus.  Nothing fancy —
the goal is a checkpoint whose activations show the outlier features of
Fig. 1 and whose quality measurably degrades under aggressive quantization.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import ModelConfig


def _batches(tokens: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    for _ in range(steps):
        idx = rng.integers(0, n, size=batch)
        x = np.stack([tokens[i:i + seq] for i in idx]).astype(np.int32)
        y = np.stack([tokens[i + 1:i + seq + 1] for i in idx]).astype(np.int32)
        yield jnp.asarray(x), jnp.asarray(y)


def loss_fn(cfg: ModelConfig, params, x, y):
    logits, _, _ = M.prefill(cfg, M.BASELINE, params, x, 0.0, 1.0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1).squeeze(-1)
    return nll.mean()


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return z, jax.tree.map(jnp.zeros_like, params)


@functools.partial(jax.jit, static_argnums=0)
def train_step(cfg: ModelConfig, params, mstate, vstate, step, x, y):
    lr, b1, b2, eps = cfg.lr, 0.9, 0.999, 1e-8
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, x, y))(params)
    mstate = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, mstate, grads)
    vstate = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, vstate, grads)
    t = step + 1
    mh = jax.tree.map(lambda m: m / (1 - b1**t), mstate)
    vh = jax.tree.map(lambda v: v / (1 - b2**t), vstate)
    params = jax.tree.map(
        lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), params, mh, vh)
    return params, mstate, vstate, loss


def evaluate_ppl(cfg: ModelConfig, params, tokens: np.ndarray,
                 seq: int | None = None) -> float:
    seq = seq or cfg.train_seq
    n = (len(tokens) - 1) // seq
    total, count = 0.0, 0
    for i in range(min(n, 32)):
        x = jnp.asarray(tokens[i * seq:(i + 1) * seq][None].astype(np.int32))
        y = jnp.asarray(tokens[i * seq + 1:(i + 1) * seq + 1][None].astype(np.int32))
        total += float(loss_fn(cfg, params, x, y)) * seq
        count += seq
    return float(np.exp(total / count))


def train(cfg: ModelConfig, tokens: np.ndarray, seed: int = 0,
          log_every: int = 100) -> dict:
    params = M.init_params(cfg, seed)
    mstate, vstate = adam_init(params)
    t0 = time.time()
    losses = []
    for step, (x, y) in enumerate(
            _batches(tokens, cfg.train_batch, cfg.train_seq, cfg.train_steps, seed)):
        params, mstate, vstate, loss = train_step(
            cfg, params, mstate, vstate, jnp.asarray(step, jnp.float32), x, y)
        losses.append(float(loss))
        if step % log_every == 0 or step == cfg.train_steps - 1:
            print(f"[{cfg.name}] step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params
