"""AOT compile path: train → rotate → lower every graph variant to HLO text.

``make artifacts`` runs this once; the rust runtime then never touches
python.  Per model config we emit into ``artifacts/<name>/``:

  weights.bin      base.* (trained, unfused), rot.* (QuaRot-rotated),
                   rnd.* (random-orthogonal-rotated, Table 8)
  manifest.json    graph inventory: file, ordered input/output specs
  *.hlo.txt        the lowered graphs:

    baseline_prefill   unrotated, fake-quant + QUIK outlier masks
    baseline_decode    unrotated, f32 KV cache (the FP16 serving baseline)
    quarot_prefill     rotated + online Hadamards + fake-quant
    quarot_decode      rotated, quantized-KV-cache decode (Pallas kernel)
    quarot_prefill_h16 Table 10: online Hadamards rounded to bf16
    collect_baseline   calibration stats (Hessians + amax) in original space
    collect_quarot     calibration stats in rotated space
    qlinear_<K>x<N>    standalone Pallas INT-GEMM linear layer (Fig 7)
    linear_<K>x<N>     f32 reference linear layer (Fig 7 baseline)
    wht_<d>            standalone online-Hadamard op (Fig 7 overhead split)

Interchange is HLO *text* (xla_extension 0.5.1 rejects jax>=0.5 protos with
64-bit ids; the text parser reassigns ids) — see /opt/xla-example/README.md.

Shared across configs: artifacts/corpus.bin, artifacts/probes.bin.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, io, model as M, quarot, train
from .configs import CONFIGS, DEFAULT_BUILD, ModelConfig
from .hadamard_utils import random_orthogonal
from .kernels import qmatmul as qmm_k

WEIGHT_ORDER = ("embed", "final_norm", "lm_head", "attn_norm", "wq", "wk",
                "wv", "wo", "ffn_norm", "wup", "wgate", "wdown")
MASK_ORDER = ("mask_attn", "mask_out", "mask_ffn", "mask_down")

_DT = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32", jnp.int8.dtype: "i8"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _weight_specs(cfg: ModelConfig) -> dict:
    d, da, dkv, dff, v, L = (cfg.d_model, cfg.d_attn, cfg.d_kv, cfg.d_ff,
                             cfg.vocab, cfg.n_layers)
    return {
        "embed": _spec((v, d)), "final_norm": _spec((d,)),
        "lm_head": _spec((d, v)), "attn_norm": _spec((L, d)),
        "wq": _spec((L, d, da)), "wk": _spec((L, d, dkv)),
        "wv": _spec((L, d, dkv)), "wo": _spec((L, da, d)),
        "ffn_norm": _spec((L, d)), "wup": _spec((L, d, dff)),
        "wgate": _spec((L, d, dff)), "wdown": _spec((L, dff, d)),
    }


def _mask_specs(cfg: ModelConfig) -> dict:
    L = cfg.n_layers
    return {
        "mask_attn": _spec((L, cfg.d_model)),
        "mask_out": _spec((L, cfg.d_attn)),
        "mask_ffn": _spec((L, cfg.d_model)),
        "mask_down": _spec((L, cfg.d_ff)),
    }


def _cache_specs(cfg: ModelConfig) -> list:
    L, B, S = cfg.n_layers, cfg.decode_batch, cfg.cache_seq
    hk, dh = cfg.n_kv_heads, cfg.d_head
    ng = dh // cfg.group
    code = _spec((L, B, S, hk, dh), jnp.int8)
    side = _spec((L, B, S, hk, ng))
    return [code, side, side, code, side, side]


def _io_entry(name, s):
    return {"name": name, "dtype": _DT[s.dtype], "shape": list(s.shape)}


class GraphSet:
    """Collects lowered graphs + manifest entries for one config."""

    def __init__(self, cfg: ModelConfig, outdir: str):
        self.cfg, self.outdir = cfg, outdir
        self.manifest = {}

    def lower(self, name: str, fn, inputs: list[tuple[str, jax.ShapeDtypeStruct]],
              outputs: list[str]):
        specs = [s for _, s in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.outdir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *specs)
        flat, _ = jax.tree.flatten(out_shapes)
        self.manifest[name] = {
            "file": fname,
            "inputs": [_io_entry(n, s) for n, s in inputs],
            "outputs": [_io_entry(n, s) for n, s in zip(outputs, flat)],
        }
        print(f"  lowered {name}: {len(text) / 1e6:.2f} MB hlo", flush=True)


def build_graphs(cfg: ModelConfig, outdir: str) -> dict:
    gs = GraphSet(cfg, outdir)
    B, S = 1, cfg.max_seq
    DB, CS = cfg.decode_batch, cfg.cache_seq
    L, hk, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    wspecs = _weight_specs(cfg)
    mspecs = _mask_specs(cfg)
    weights_in = [(k, wspecs[k]) for k in WEIGHT_ORDER]
    masks_in = [(k, mspecs[k]) for k in MASK_ORDER]
    scalars = [("act_levels", _spec((1,))), ("act_clip", _spec((1,)))]
    kv_scalars = [("k_qmax", _spec((1,))), ("v_qmax", _spec((1,))),
                  ("kv_clip", _spec((1,)))]
    tok_prefill = ("tokens", _spec((B, S), jnp.int32))
    tok_decode = ("tokens", _spec((DB,), jnp.int32))
    lens_in = ("cur_lens", _spec((DB,), jnp.int32))
    cache_names = ["k_codes", "k_scale", "k_zero", "v_codes", "v_scale", "v_zero"]
    cache_in = list(zip(cache_names, _cache_specs(cfg)))
    kv_out = ["k_rot", "v_rot"]

    def wdict(args, keys):
        return dict(zip(keys, args))

    # ---- prefill graphs ----
    def mk_prefill(mode, with_masks):
        def fn(tokens, levels, clip, k_qmax, v_qmax, kv_clip, *rest):
            if with_masks:
                masks = wdict(rest[:4], MASK_ORDER)
                params = wdict(rest[4:], WEIGHT_ORDER)
            else:
                masks, params = None, wdict(rest, WEIGHT_ORDER)
            return M.prefill(cfg, mode, params, tokens, levels[0], clip[0],
                             masks=masks,
                             kv_args=(k_qmax[0], v_qmax[0], kv_clip[0]))
        return fn

    gs.lower("baseline_prefill", mk_prefill(M.BASELINE_QUANT, True),
             [tok_prefill] + scalars + kv_scalars + masks_in + weights_in,
             ["logits"] + kv_out)
    gs.lower("quarot_prefill", mk_prefill(M.QUAROT, False),
             [tok_prefill] + scalars + kv_scalars + weights_in,
             ["logits"] + kv_out)
    gs.lower("quarot_prefill_h16", mk_prefill(M.QUAROT_BF16HAD, False),
             [tok_prefill] + scalars + kv_scalars + weights_in,
             ["logits"] + kv_out)

    # ---- decode graphs ----
    def mk_decode(mode):
        def fn(tokens, cur_lens, kc, ks, kz, vc, vs, vz, levels, clip, *ws):
            params = wdict(ws, WEIGHT_ORDER)
            return M.decode(cfg, mode, params, tokens, cur_lens,
                            (kc, ks, kz, vc, vs, vz), levels[0], clip[0])
        return fn

    gs.lower("quarot_decode", mk_decode(M.QUAROT),
             [tok_decode, lens_in] + cache_in + scalars + weights_in,
             ["logits", "k_new", "v_new"])

    # FP16-equivalent baseline decode: raw f32 cache, no rotation/quant.
    def mk_baseline_decode():
        fkc = ("k_cache", _spec((L, DB, CS, hk, dh)))
        fvc = ("v_cache", _spec((L, DB, CS, hk, dh)))

        def fn(tokens, cur_lens, k_cache, v_cache, levels, clip, *ws):
            params = wdict(ws, WEIGHT_ORDER)
            ng = dh // cfg.group
            one = jnp.ones((L, DB, CS, hk, ng), jnp.float32)
            zero = jnp.zeros((L, DB, CS, hk, ng), jnp.float32)
            # f32 cache flows through the same attention math with scale=1,
            # zero=0; codes arg takes the raw values (ref path, no int cast).
            mode = M.Mode(rotated=False, quant_acts=True, use_kernels=False)
            return M.decode(cfg, mode, params, tokens, cur_lens,
                            (k_cache, one, zero, v_cache, one, zero),
                            levels[0], clip[0])
        return fn, [tok_decode, lens_in, fkc, fvc] + scalars + weights_in

    fn, ins = mk_baseline_decode()
    gs.lower("baseline_decode", fn, ins, ["logits", "k_new", "v_new"])

    # ---- calibration graphs ----
    def mk_collect(mode):
        def fn(tokens, *ws):
            return M.collect(cfg, mode, wdict(ws, WEIGHT_ORDER), tokens)
        return fn

    stat_out = ["h_attn", "amax_attn", "h_out", "amax_out",
                "h_ffn", "amax_ffn", "h_down", "amax_down", "logit_amax"]
    gs.lower("collect_baseline", mk_collect(M.BASELINE), [tok_prefill] + weights_in,
             stat_out)
    gs.lower("collect_quarot", mk_collect(M.QUAROT), [tok_prefill] + weights_in,
             stat_out)

    # ---- standalone kernel graphs (Fig 7 / Table 14 artifacts) ----
    t = 128
    for (k, n) in {(cfg.d_ff, cfg.d_model), (cfg.d_model, cfg.d_ff)}:
        gs.lower(
            f"qlinear_{k}x{n}",
            lambda x, wi, wsc: qmm_k.qmatmul(x, wi, wsc, levels=7, clip=0.9),
            [("x", _spec((t, k))), ("w_int", _spec((k, n), jnp.int8)),
             ("w_scale", _spec((n,)))], ["y"])
        gs.lower(
            f"linear_{k}x{n}", lambda x, w: x @ w,
            [("x", _spec((t, k))), ("w", _spec((k, n)))], ["y"])
    from .kernels import hadamard as hk
    gs.lower(f"wht_{cfg.d_ff}", lambda x: hk.wht(x),
             [("x", _spec((t, cfg.d_ff)))], ["y"])
    return gs.manifest


def build_config(cfg: ModelConfig, root: str, corpus: dict[str, np.ndarray],
                 force: bool = False) -> None:
    outdir = os.path.join(root, cfg.name)
    os.makedirs(outdir, exist_ok=True)
    wpath = os.path.join(outdir, "weights.bin")
    mpath = os.path.join(outdir, "manifest.json")
    if not force and os.path.exists(wpath) and os.path.exists(mpath):
        print(f"[{cfg.name}] artifacts exist, skipping (use --force to rebuild)")
        return

    print(f"[{cfg.name}] training ({cfg.param_count() / 1e6:.1f}M params)...",
          flush=True)
    params = train.train(cfg, corpus["train"])
    ppl = train.evaluate_ppl(cfg, params, corpus["eval"])
    print(f"[{cfg.name}] eval ppl {ppl:.3f}")

    np_params = {k: np.asarray(v) for k, v in params.items()}
    # explicit Q so the sign vector can ship to rust (model/transform.rs
    # rebuilds the identical rotation from `meta.q_signs`)
    from .hadamard_utils import hadamard_matrix, random_signs
    signs = random_signs(cfg.d_model, seed=17)
    q_had = hadamard_matrix(cfg.d_model) * signs[None, :]
    rot = quarot.rotate_params(cfg, np_params, q_matrix=q_had)
    # the random-orthogonal Q ships whole (d x d) — unlike the Hadamard
    # rotation it is not reconstructible from a seed on the rust side, so
    # `quarot verify --rotation random` reads it back from the artifact
    q_rnd = random_orthogonal(cfg.d_model, seed=23)
    rnd = quarot.rotate_params(cfg, np_params, q_matrix=q_rnd)
    tensors = {"meta.q_signs": signs.astype(np.float32),
               "meta.rnd_q": q_rnd.astype(np.float32)}
    for pre, ps in (("base", np_params), ("rot", rot), ("rnd", rnd)):
        for k, v in ps.items():
            tensors[f"{pre}.{k}"] = np.asarray(v, np.float32)
    io.write_weights(wpath, tensors)

    print(f"[{cfg.name}] lowering graphs...", flush=True)
    manifest = {
        "model": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "d_head": cfg.d_head,
            "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "cache_seq": cfg.cache_seq, "decode_batch": cfg.decode_batch,
            "kv_group": cfg.group, "rope_theta": cfg.rope_theta,
            "train_ppl": ppl,
        },
        "weight_order": list(WEIGHT_ORDER),
        "mask_order": list(MASK_ORDER),
        "graphs": build_graphs(cfg, outdir),
    }
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[{cfg.name}] done.")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", nargs="*", default=DEFAULT_BUILD)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cpath = os.path.join(args.out, "corpus.bin")
    ppath = os.path.join(args.out, "probes.bin")
    vocab = CONFIGS[args.configs[0]].vocab
    if args.force or not os.path.exists(cpath):
        print("building corpus...", flush=True)
        splits = data.build_splits(vocab)
        io.write_corpus(cpath, vocab, splits)
        io.write_probes(ppath, data.build_probes(vocab))
    _, corpus = io.read_corpus(cpath)

    for name in args.configs:
        build_config(CONFIGS[name], args.out, corpus, force=args.force)


if __name__ == "__main__":
    main()
