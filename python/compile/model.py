"""LLaMA-architecture transformer with the QuaRot forward-pass rewrites.

One parametric forward function serves every graph variant the rust runtime
loads (DESIGN.md §3).  Weights are *graph arguments* (never constants) so the
same lowered executable evaluates any quantized weight set the rust
quantization toolchain produces.  A :class:`Mode` selects which QuaRot
machinery is inserted:

* ``rotated``      — insert the online Hadamard ops (Stages 1b/1c/1d).  The
                     *fused* rotations (Stage 1a) live in the weights, applied
                     offline by quarot.py; the graph is agnostic to them.
* ``quant_acts``   — insert per-token fake-quant in front of every weight
                     matrix (Stage 2b).  ``act_levels <= 0`` at call time
                     degrades to a pass-through, so quantized graphs subsume
                     the FP16 baseline.
* ``outlier_mask`` — per-layer per-channel masks that keep marked activation
                     features unquantized (the QUIK baseline of Table 1;
                     QuaRot itself always runs with zero masks).
* ``had_bf16``     — round online-Hadamard outputs to bf16 (Table 10's FP16-
                     Hadamard ablation, emulated on the f32 CPU runtime).

Layer loop is a ``lax.scan`` over stacked (L, ...) weights: small HLO, and
the Pallas kernels lower inside the loop body.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import hadamard as hk
from .kernels import kv_attention as kva
from .kernels import quant as qk
from .kernels import ref

_NORM_EPS = 1e-5


@dataclasses.dataclass(frozen=True)
class Mode:
    rotated: bool = False
    quant_acts: bool = False
    outlier_mask: bool = False
    had_bf16: bool = False
    use_kernels: bool = True   # False → pure-jnp refs (fast tracing in tests)


BASELINE = Mode()
BASELINE_QUANT = Mode(quant_acts=True, outlier_mask=True)
QUAROT = Mode(rotated=True, quant_acts=True)
QUAROT_BF16HAD = Mode(rotated=True, quant_acts=True, had_bf16=True)


# --- parameter pytree ---------------------------------------------------------

LAYER_KEYS = ("attn_norm", "wq", "wk", "wv", "wo",
              "ffn_norm", "wup", "wgate", "wdown")
GLOBAL_KEYS = ("embed", "final_norm", "lm_head")


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Random init with the outlier-inducing embedding recipe (DESIGN.md §1)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 12)
    d, da, dkv, dff, v, L = (cfg.d_model, cfg.d_attn, cfg.d_kv, cfg.d_ff,
                             cfg.vocab, cfg.n_layers)

    def w(k, shape, scale=None):
        scale = scale or (1.0 / jnp.sqrt(shape[0]))
        return jax.random.normal(k, shape, jnp.float32) * scale

    embed = w(ks[0], (v, d), 0.7)
    if cfg.outlier_channels > 0:
        # heat up a few channels: pre-norm residual streams keep them hot,
        # reproducing the outlier features of Fig. 1.
        hot = jnp.zeros((d,)).at[: cfg.outlier_channels].set(1.0)
        embed = embed * (1.0 + (cfg.outlier_scale - 1.0) * hot[None, :])
    return {
        "embed": embed,
        "final_norm": jnp.ones((d,)),
        "lm_head": w(ks[1], (d, v)),
        "attn_norm": jnp.ones((L, d)),
        "wq": w(ks[2], (L, d, da)),
        "wk": w(ks[3], (L, d, dkv)),
        "wv": w(ks[4], (L, d, dkv)),
        "wo": w(ks[5], (L, da, d), scale=0.5 / jnp.sqrt(da)),
        "ffn_norm": jnp.ones((L, d)),
        "wup": w(ks[6], (L, d, dff)),
        "wgate": w(ks[7], (L, d, dff)),
        "wdown": w(ks[8], (L, dff, d), scale=0.5 / jnp.sqrt(dff)),
    }


# --- building blocks -----------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    """Pre-norm RMSNorm; computed in f32 like the paper (Stage 2b note)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + _NORM_EPS) * gamma


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding on (..., T, H, dh); positions (..., T)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _maybe_bf16(x: jnp.ndarray, mode: Mode) -> jnp.ndarray:
    if mode.had_bf16:
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    return x


def _wht(x: jnp.ndarray, mode: Mode) -> jnp.ndarray:
    y = hk.wht_lastdim(x) if mode.use_kernels else ref.wht_rows(x)
    return _maybe_bf16(y, mode)


def _had_heads(x: jnp.ndarray, n_heads: int, mode: Mode) -> jnp.ndarray:
    y = hk.had_heads(x, n_heads) if mode.use_kernels else ref.had_heads(x, n_heads)
    return _maybe_bf16(y, mode)


def _had_headdim(x: jnp.ndarray, mode: Mode) -> jnp.ndarray:
    y = hk.had_headdim(x) if mode.use_kernels else ref.wht_rows(x)
    return _maybe_bf16(y, mode)


def _kv_fake_quant_traced(x, qmax, clip, group: int):
    """Group-wise asymmetric fake-quant with *traced* qmax (0 → off).

    The prefill graphs use this to emulate cache quantization during
    perplexity evaluation (paper Tables 1/3/6): attention consumes the
    fake-quantized keys/values exactly as decode would consume the
    dequantized cache.
    """
    qmax = jnp.asarray(qmax, x.dtype)
    clip = jnp.asarray(clip, x.dtype)
    shape = x.shape
    g = x.reshape(*shape[:-1], shape[-1] // group, group)
    mx = jnp.max(g, axis=-1, keepdims=True)
    mn = jnp.min(g, axis=-1, keepdims=True)
    center = (mx + mn) * 0.5
    half = (mx - mn) * 0.5 * clip
    lo = center - half
    scale = jnp.maximum(2.0 * half, 1e-8) / jnp.maximum(qmax, 1.0)
    q = jnp.clip(jnp.round((g - lo) / scale), 0.0, jnp.maximum(qmax, 1.0))
    y = (q * scale + lo).reshape(shape)
    return jnp.where(qmax > 0, y, x)


def _quant_site(x, levels, clip, mask, mode: Mode):
    """Activation fake-quant at one of the four per-layer sites.

    ``mask`` (channels,) ∈ {0,1}: 1 → feature kept in high precision and
    excluded from the shared scale (QUIK-style outlier retention).
    """
    if not mode.quant_acts:
        return x
    if mode.outlier_mask and mask is not None:
        keep = mask
        scaled = jnp.abs(x) * (1.0 - keep)
        amax = jnp.max(scaled, axis=-1, keepdims=True)
        lv = jnp.asarray(levels, x.dtype)
        s = jnp.maximum(amax * jnp.asarray(clip, x.dtype), 1e-8) / jnp.maximum(lv, 1.0)
        q = jnp.clip(jnp.round(x / s), -lv, lv) * s
        q = jnp.where(keep > 0, x, q)
        return jnp.where(lv > 0, q, x)
    if mode.use_kernels:
        return qk.fake_quant_lastdim(x, levels, clip)
    return ref.fake_quant_act(x, levels, clip)


# --- layer body ------------------------------------------------------------------

def _attention_prefill(q, k, v, cfg: ModelConfig):
    """Causal f32 attention (paper: prefill attends over dequantized KV)."""
    b, s, h, dh = q.shape
    rep = cfg.n_heads // cfg.n_kv_heads
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _layer_prefill(cfg: ModelConfig, mode: Mode, x, positions, lw, levels, clip,
                   kv_args=None):
    b, s, d = x.shape
    h_att = rmsnorm(x, lw["attn_norm"])
    h_att = _quant_site(h_att, levels, clip, lw.get("mask_attn"), mode)

    q = (h_att @ lw["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (h_att @ lw["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = (h_att @ lw["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if mode.rotated:  # Stage 1d: online head-wise Hadamard after RoPE
        q = _had_headdim(q, mode)
        k = _had_headdim(k, mode)

    # cache-quantization emulation (prefill ppl with quantized KV)
    k_att, v_att = k, v
    if kv_args is not None:
        k_qmax, v_qmax, kv_clip = kv_args
        k_att = _kv_fake_quant_traced(k, k_qmax, kv_clip, cfg.group)
        v_att = _kv_fake_quant_traced(v, v_qmax, kv_clip, cfg.group)

    att = _attention_prefill(q, k_att, v_att, cfg).reshape(b, s, cfg.d_attn)
    if mode.rotated:  # Stage 1c completion: Hadamard heads before out-proj
        att = _had_heads(att, cfg.n_heads, mode)
    att = _quant_site(att, levels, clip, lw.get("mask_out"), mode)
    x = x + att @ lw["wo"]

    h_ffn = rmsnorm(x, lw["ffn_norm"])
    h_ffn = _quant_site(h_ffn, levels, clip, lw.get("mask_ffn"), mode)
    up = h_ffn @ lw["wup"]
    gate = h_ffn @ lw["wgate"]
    act = up * jax.nn.silu(gate)
    if mode.rotated:  # Stage 1b: online Hadamard before down-proj
        act = _wht(act, mode)
    act = _quant_site(act, levels, clip, lw.get("mask_down"), mode)
    x = x + act @ lw["wdown"]
    return x, (k, v)


def prefill(cfg: ModelConfig, mode: Mode, params: dict, tokens: jnp.ndarray,
            act_levels, act_clip, masks: dict | None = None, kv_args=None):
    """Full-sequence forward.  tokens (B, S) int32.

    Returns (logits (B,S,V), k (L,B,S,Hk,dh), v (L,B,S,Hk,dh)); k is
    post-RoPE (+ post-Hadamard when rotated) — exactly what the paper's
    Post-RoPE cache stores; v carries the fused (I⊗H_dh) rotation.

    ``kv_args = (k_qmax, v_qmax, kv_clip)`` (traced scalars, qmax 0 → off)
    makes attention consume fake-quantized K/V, emulating a quantized cache
    for perplexity measurement (paper Tables 1/3/6).
    """
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"][tokens]

    layer_weights = {k: params[k] for k in LAYER_KEYS}
    if masks is not None:
        layer_weights.update(masks)

    def body(x, lw):
        x, kv = _layer_prefill(cfg, mode, x, positions, lw, act_levels, act_clip,
                               kv_args=kv_args)
        return x, kv

    x, (ks, vs) = jax.lax.scan(body, x, layer_weights)
    h = rmsnorm(x, params["final_norm"])
    logits = h @ params["lm_head"]
    return logits, ks, vs


def _layer_decode(cfg: ModelConfig, mode: Mode, x, positions, cur_lens,
                  lw, cache, levels, clip):
    """Single-token step.  x (B, d); cache = per-layer quantized KV args."""
    b, d = x.shape
    h_att = rmsnorm(x, lw["attn_norm"])
    h_att = _quant_site(h_att, levels, clip, lw.get("mask_attn"), mode)

    q = (h_att @ lw["wq"]).reshape(b, 1, cfg.n_heads, cfg.d_head)
    k = (h_att @ lw["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    v = (h_att @ lw["wv"]).reshape(b, cfg.n_kv_heads, cfg.d_head)
    q = rope(q, positions[:, None], cfg.rope_theta)
    k = rope(k, positions[:, None], cfg.rope_theta)
    if mode.rotated:
        q = _had_headdim(q, mode)
        k = _had_headdim(k, mode)
    q = q[:, 0]  # (B, H, dh)
    k_new = k[:, 0]  # (B, Hk, dh)
    v_new = v

    kc, ksc, kz, vc, vsc, vz = cache
    sm = 1.0 / float(cfg.d_head) ** 0.5  # python float: kernels take it static
    fn = kva.kv_decode_attention if mode.use_kernels else ref.kv_decode_attention
    att = fn(q, kc, ksc, kz, vc, vsc, vz, k_new, v_new, cur_lens,
             group=cfg.group, sm_scale=sm)          # (B, H, dh)
    att = att.reshape(b, cfg.d_attn)
    if mode.rotated:
        att = _had_heads(att, cfg.n_heads, mode)
    att = _quant_site(att, levels, clip, lw.get("mask_out"), mode)
    x = x + att @ lw["wo"]

    h_ffn = rmsnorm(x, lw["ffn_norm"])
    h_ffn = _quant_site(h_ffn, levels, clip, lw.get("mask_ffn"), mode)
    up = h_ffn @ lw["wup"]
    gate = h_ffn @ lw["wgate"]
    act = up * jax.nn.silu(gate)
    if mode.rotated:
        act = _wht(act, mode)
    act = _quant_site(act, levels, clip, lw.get("mask_down"), mode)
    x = x + act @ lw["wdown"]
    return x, (k_new, v_new)


def decode(cfg: ModelConfig, mode: Mode, params: dict, tokens: jnp.ndarray,
           cur_lens: jnp.ndarray, caches: tuple, act_levels, act_clip,
           masks: dict | None = None):
    """One decode step for a batch of slots.

    tokens (B,) int32; cur_lens (B,) int32 (doubles as the RoPE position);
    caches = (k_codes (L,B,S,Hk,dh) i8, k_scale (L,B,S,Hk,ng) f32, k_zero,
              v_codes, v_scale, v_zero).
    Returns (logits (B,V), k_new (L,B,Hk,dh), v_new (L,B,Hk,dh)); the rust
    coordinator quantizes k_new/v_new into the cache (the paper's Append).
    """
    x = params["embed"][tokens]
    positions = cur_lens.astype(jnp.int32)

    layer_weights = {k: params[k] for k in LAYER_KEYS}
    if masks is not None:
        layer_weights.update(masks)

    def body(x, lw_cache):
        lw, cache = lw_cache
        x, kv = _layer_decode(cfg, mode, x, positions, cur_lens, lw, cache,
                              act_levels, act_clip)
        return x, kv

    x, (k_new, v_new) = jax.lax.scan(body, x, (layer_weights, caches))
    h = rmsnorm(x, params["final_norm"])
    logits = h @ params["lm_head"]
    return logits, k_new, v_new


def collect(cfg: ModelConfig, mode: Mode, params: dict, tokens: jnp.ndarray):
    """Calibration pass: per-layer Hessian contributions + channel amax.

    Runs the *rotated, unquantized* forward and returns, per layer and per
    quantization site, X^T X over all tokens (GPTQ Hessian contribution) and
    per-channel max |x| (SmoothQuant / QUIK statistics).  Shipping H instead
    of raw activations keeps the artifact interface small.
    """
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"][tokens]
    layer_weights = {k: params[k] for k in LAYER_KEYS}
    nomode = dataclasses.replace(mode, quant_acts=False)

    def stats(h):
        f = h.reshape(-1, h.shape[-1])
        return f.T @ f, jnp.max(jnp.abs(f), axis=0)

    def body(x, lw):
        h_att = rmsnorm(x, lw["attn_norm"])
        s1 = stats(h_att)
        q = (h_att @ lw["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
        k = (h_att @ lw["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        v = (h_att @ lw["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if nomode.rotated:
            q = _had_headdim(q, nomode)
            k = _had_headdim(k, nomode)
        att = _attention_prefill(q, k, v, cfg).reshape(b, s, cfg.d_attn)
        if nomode.rotated:
            att = _had_heads(att, cfg.n_heads, nomode)
        s2 = stats(att)
        x = x + att @ lw["wo"]
        h_ffn = rmsnorm(x, lw["ffn_norm"])
        s3 = stats(h_ffn)
        up = h_ffn @ lw["wup"]
        act = up * jax.nn.silu(h_ffn @ lw["wgate"])
        if nomode.rotated:
            act = _wht(act, nomode)
        s4 = stats(act)
        x = x + act @ lw["wdown"]
        return x, (s1, s2, s3, s4)

    x, sites = jax.lax.scan(body, x, layer_weights)
    (h1, a1), (h2, a2), (h3, a3), (h4, a4) = sites
    # per-channel |logit| maxima: a real diagnostic, and it keeps
    # final_norm/lm_head live in the lowered module (XLA prunes unused
    # parameters, which would desync the manifest ABI).
    h = rmsnorm(x, params["final_norm"])
    logit_amax = jnp.max(jnp.abs((h @ params["lm_head"]).reshape(-1, cfg.vocab)),
                         axis=0)
    return h1, a1, h2, a2, h3, a3, h4, a4, logit_amax


# --- convenience: generate with a python loop (tests / training eval) -----------

def greedy_generate(cfg: ModelConfig, mode: Mode, params: dict,
                    prompt: jnp.ndarray, n_new: int,
                    kv_bits: int = 8, kv_clip: float = 1.0) -> jnp.ndarray:
    """Reference generation loop (prefill + quantized-cache decode).

    Mirrors exactly what the rust coordinator does; used by python tests to
    pin the expected end-to-end behaviour.
    """
    b, s0 = prompt.shape
    S = cfg.cache_seq
    L, Hk, dh, ng = cfg.n_layers, cfg.n_kv_heads, cfg.d_head, cfg.d_head // cfg.group
    logits, ks, vs = prefill(cfg, mode, params, prompt, 0.0, 1.0)

    def quant(xs):
        return ref.kv_quant(xs, kv_bits, cfg.group, kv_clip)

    kc = jnp.zeros((L, b, S, Hk, dh), jnp.int8)
    ksc = jnp.zeros((L, b, S, Hk, ng), jnp.float32)
    kz = jnp.zeros((L, b, S, Hk, ng), jnp.float32)
    vc, vsc, vz = jnp.zeros_like(kc), jnp.zeros_like(ksc), jnp.zeros_like(kz)
    q, sc, z = quant(ks)
    kc, ksc, kz = kc.at[:, :, :s0].set(q), ksc.at[:, :, :s0].set(sc), kz.at[:, :, :s0].set(z)
    q, sc, z = quant(vs)
    vc, vsc, vz = vc.at[:, :, :s0].set(q), vsc.at[:, :, :s0].set(sc), vz.at[:, :, :s0].set(z)

    out = [jnp.argmax(logits[:, -1], axis=-1)]
    cur = jnp.full((b,), s0, jnp.int32)
    for _ in range(n_new - 1):
        logits, k_new, v_new = decode(cfg, mode, params, out[-1], cur,
                                      (kc, ksc, kz, vc, vsc, vz), 0.0, 1.0)
        q, sc, z = quant(k_new[:, :, None])
        kc = kc.at[jnp.arange(L)[:, None], jnp.arange(b)[None], cur[None]].set(q[:, :, 0])
        ksc = ksc.at[jnp.arange(L)[:, None], jnp.arange(b)[None], cur[None]].set(sc[:, :, 0])
        kz = kz.at[jnp.arange(L)[:, None], jnp.arange(b)[None], cur[None]].set(z[:, :, 0])
        q, sc, z = quant(v_new[:, :, None])
        vc = vc.at[jnp.arange(L)[:, None], jnp.arange(b)[None], cur[None]].set(q[:, :, 0])
        vsc = vsc.at[jnp.arange(L)[:, None], jnp.arange(b)[None], cur[None]].set(sc[:, :, 0])
        vz = vz.at[jnp.arange(L)[:, None], jnp.arange(b)[None], cur[None]].set(z[:, :, 0])
        cur = cur + 1
        out.append(jnp.argmax(logits, axis=-1))
    return jnp.stack(out, axis=1)
