"""Synthetic corpus + probe-task generator (the WikiText-2 / zero-shot stand-in).

A zipfian bigram-mixture language (DESIGN.md §1): every token has a
power-law-weighted successor table over a permuted vocabulary, mixed with a
global unigram zipf.  The chain has enough structure for a small transformer
to reach ppl well below the unigram floor, which is what the quantization
tables need — a model whose quality measurably *degrades* when quantized.

The probe tasks proxy the paper's six zero-shot suites (Table 2).  Each is a
multiple-choice ranking task built from held-out chain samples, with
difficulty knobs (context length, number of choices, distractor source)
chosen so the six tasks span easy→hard like PIQA→ARC-c do:

  piqa-proxy   ctx 8,  2 choices, unigram distractors       (easy)
  wino-proxy   ctx 12, 2 choices, 1-token-swapped gold      (medium)
  hswag-proxy  ctx 16, 4 choices, wrong-start chain samples (medium)
  arce-proxy   ctx 6,  4 choices, unigram distractors       (easy)
  arcc-proxy   ctx 6,  4 choices, bigram-plausible distractors (hard)
  lambada-proxy ctx 24, exact next-token match              (hard)
"""

from __future__ import annotations

import numpy as np


def _zipf_probs(n: int, alpha: float, rng: np.random.Generator) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return p[rng.permutation(n)]


class BigramLanguage:
    """The synthetic data-generating process."""

    def __init__(self, vocab: int, seed: int = 0, alpha: float = 1.2,
                 mix_unigram: float = 0.15):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.unigram = _zipf_probs(vocab, alpha, rng)
        # per-token successor tables: zipf over an independent permutation
        self.bigram = np.stack([_zipf_probs(vocab, alpha, rng) for _ in range(vocab)])
        self.trans = (1 - mix_unigram) * self.bigram + mix_unigram * self.unigram[None]
        self.trans /= self.trans.sum(axis=1, keepdims=True)

    def sample(self, n: int, rng: np.random.Generator,
               start: int | None = None) -> np.ndarray:
        out = np.empty(n, np.uint16)
        tok = start if start is not None else rng.integers(self.vocab)
        for i in range(n):
            tok = rng.choice(self.vocab, p=self.trans[tok])
            out[i] = tok
        return out

    def sample_fast(self, n: int, rng: np.random.Generator) -> np.ndarray:
        """Vectorized inverse-CDF sampling (the python loop is too slow >100k)."""
        cdf = np.cumsum(self.trans, axis=1)
        out = np.empty(n, np.uint16)
        tok = int(rng.integers(self.vocab))
        us = rng.random(n)
        for i in range(n):
            tok = int(np.searchsorted(cdf[tok], us[i]))
            out[i] = min(tok, self.vocab - 1)
        return out


def build_splits(vocab: int, seed: int = 0, train: int = 150_000,
                 calib: int = 16_384, evals: int = 16_384) -> dict[str, np.ndarray]:
    lang = BigramLanguage(vocab, seed)
    rng = np.random.default_rng(seed + 1)
    return {
        "train": lang.sample_fast(train, rng),
        "calib": lang.sample_fast(calib, rng),
        "eval": lang.sample_fast(evals, rng),
    }


def build_probes(vocab: int, seed: int = 0, n_items: int = 200) -> list[dict]:
    lang = BigramLanguage(vocab, seed)
    rng = np.random.default_rng(seed + 2)

    def chain(n, start=None):
        return lang.sample(n, rng, start)

    def unigram_seq(n):
        return rng.choice(vocab, size=n, p=lang.unigram).astype(np.uint16)

    def mc_task(name, ctx_len, cont_len, n_choices, distractor):
        items = []
        for _ in range(n_items):
            seq = chain(ctx_len + cont_len)
            ctx, gold_cont = seq[:ctx_len], seq[ctx_len:]
            choices = [gold_cont]
            while len(choices) < n_choices:
                d = distractor(ctx, gold_cont, cont_len)
                if not any(np.array_equal(d, c) for c in choices):
                    choices.append(d)
            order = rng.permutation(n_choices)
            items.append({
                "ctx": ctx,
                "choices": [choices[i] for i in order],
                "gold": int(np.where(order == 0)[0][0]),
            })
        return {"name": name, "items": items}

    def d_unigram(ctx, gold, n):
        return unigram_seq(n)

    def d_swap(ctx, gold, n):
        d = gold.copy()
        i = rng.integers(n)
        d[i] = rng.integers(vocab)
        return d

    def d_wrong_start(ctx, gold, n):
        return chain(n, start=int(rng.integers(vocab)))

    def d_bigram(ctx, gold, n):
        # chain-plausible but conditioned on a *perturbed* context ending —
        # locally well-formed (hard) yet distinguishable from the gold
        # continuation, unlike sampling from the true conditional
        wrong = int((int(ctx[-1]) + 1 + rng.integers(vocab - 1)) % vocab)
        return chain(n, start=wrong)

    tasks = [
        mc_task("piqa-proxy", 8, 3, 2, d_unigram),
        mc_task("wino-proxy", 12, 3, 2, d_swap),
        mc_task("hswag-proxy", 16, 4, 4, d_wrong_start),
        mc_task("arce-proxy", 6, 2, 4, d_unigram),
        mc_task("arcc-proxy", 6, 2, 4, d_bigram),
    ]
    # lambada-proxy: exact next-token prediction
    items = []
    for _ in range(n_items):
        seq = chain(25)
        items.append({"ctx": seq[:24], "choices": [], "gold_token": int(seq[24])})
    tasks.append({"name": "lambada-proxy", "items": items})
    return tasks
