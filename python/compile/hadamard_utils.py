"""Dense Hadamard-matrix construction and transform helpers.

QuaRot (Sec. 3.1) needs Hadamard matrices of size ``d`` for every dimension it
rotates: the hidden size (fused rotation ``Q``), the FFN intermediate size
(online transform before ``W_down``), the head dimension (``H_{d_h}``) and the
number of heads (``H_{n_h}``).  For ``d = 2^n`` these are Sylvester
(Walsh-Hadamard) constructions; for ``d = 2^n * m`` with ``m`` in a small table
of known Hadamard sizes we use the Kronecker construction
``H_d = H_{2^n} ⊗ H_m`` exactly as the paper describes (citing Sloane's
tables).  We ship ``H_12`` and ``H_20`` which cover every dimension used by the
model configs in this repo (and the LLaMA FFN sizes 11008/13824 in spirit).

Everything here is *build-time only*: the dense matrices are used to (a) fuse
rotations into weights (quarot.py), and (b) serve as oracles for the fast
Pallas WHT kernel (kernels/hadamard.py) and the rust `hadamard` module.
"""

from __future__ import annotations

import numpy as np

# --- known Hadamard matrices of non-power-of-two order -----------------------
# First rows of circulant-ish constructions from Sloane's tables (had.12,
# had.20.will).  We store full matrices generated from the standard Paley
# construction to keep this file self-contained, then verify orthogonality at
# import time (cheap, and guards against transcription bugs).


def _paley_hadamard(q: int) -> np.ndarray:
    """Paley construction I: Hadamard matrix of order q+1 for prime q ≡ 3 mod 4."""
    assert q % 4 == 3
    residues = {(i * i) % q for i in range(1, q)}

    def chi(a: int) -> int:
        a %= q
        if a == 0:
            return 0
        return 1 if a in residues else -1

    n = q + 1
    h = np.ones((n, n), dtype=np.int64)
    # Jacobsthal matrix
    for i in range(q):
        for j in range(q):
            if i == j:
                h[i + 1, j + 1] = -1
            else:
                h[i + 1, j + 1] = chi(j - i)
    # first row/col all ones; fix signs: H = [[1, 1...],[1^T, Q - I]] variant
    return h


HAD_12 = _paley_hadamard(11)
HAD_20 = _paley_hadamard(19)

for _m in (HAD_12, HAD_20):
    _n = _m.shape[0]
    assert (_m @ _m.T == _n * np.eye(_n, dtype=np.int64)).all(), "bad Hadamard table"

_KNOWN = {1: np.ones((1, 1), dtype=np.int64), 12: HAD_12, 20: HAD_20}


def decompose_dim(d: int) -> tuple[int, int]:
    """Split ``d = 2^n * m`` with m in the known-Hadamard table.

    Returns (pow2_part, m).  Raises if no decomposition exists.
    """
    for m in sorted(_KNOWN, reverse=True):  # prefer the largest known factor
        if d % m == 0:
            p = d // m
            if p & (p - 1) == 0:  # power of two (incl. 1)
                return p, m
    raise ValueError(f"no Hadamard construction for size {d}")


def hadamard_matrix(d: int, dtype=np.float64) -> np.ndarray:
    """Orthonormal Hadamard matrix of order ``d`` (entries ±1/sqrt(d))."""
    p, m = decompose_dim(d)
    h = _KNOWN[m].astype(np.float64)
    hp = np.array([[1.0]])
    while hp.shape[0] < p:
        hp = np.block([[hp, hp], [hp, -hp]])
    full = np.kron(hp, h)  # convention: H_d = H_{2^n} ⊗ H_m
    return (full / np.sqrt(d)).astype(dtype)


def random_signs(d: int, seed: int) -> np.ndarray:
    """Deterministic ±1 sign vector for the *randomized* Hadamard (Sec. 3.1)."""
    rng = np.random.default_rng(seed)
    return rng.choice(np.array([-1.0, 1.0]), size=d)


def randomized_hadamard(d: int, seed: int, dtype=np.float64) -> np.ndarray:
    """Q = H · diag(s): the rotation QuaRot fuses into the weights."""
    return (hadamard_matrix(d) * random_signs(d, seed)[None, :]).astype(dtype)


def random_orthogonal(d: int, seed: int, dtype=np.float64) -> np.ndarray:
    """QR-of-Gaussian orthogonal matrix — the Table 8 ablation baseline."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((d, d))
    q, r = np.linalg.qr(a)
    # sign-fix so the factorization is unique/deterministic
    q = q * np.sign(np.diag(r))[None, :]
    return q.astype(dtype)


def wht_reference(x: np.ndarray) -> np.ndarray:
    """Dense-oracle Walsh-Hadamard transform of the *rows* of x: x @ H_d.

    H_d is symmetric for the pure Sylvester construction but NOT for the
    Kronecker H_{2^n} ⊗ H_m construction, so we always form x @ H explicitly.
    """
    d = x.shape[-1]
    return x @ hadamard_matrix(d, dtype=x.dtype)
