"""Pallas fast Walsh-Hadamard transform (the paper's *online Hadamard* op).

QuaRot inserts three online Hadamard transforms per transformer layer
(Sec. 4): one of size d_ff before ``W_down`` (Stage 1b), head-wise ``H_{d_h}``
on queries/keys after RoPE (Stage 1d), and the cross-head ``H_{n_h} ⊗ I``
*Hadamard heads* block before ``W_out`` (Stage 1c).  The CUDA implementation
in the paper uses warp-level butterflies (fast-hadamard-transform); here the
kernel is re-thought for a TPU-style memory hierarchy:

* the (tokens × d) activation is tiled into VMEM-sized blocks of
  ``block_tokens`` rows via ``BlockSpec`` — the HBM↔VMEM schedule replaces the
  CUDA threadblock staging;
* within a block the transform is log2(p) butterfly stages expressed as
  reshape + add/sub over the trailing axis, which vectorizes onto the VPU's
  (8, 128) lanes with no matmul at all;
* the odd factor m of d = 2^n·m (m ∈ {1, 12, 20}, Kronecker construction,
  Sec. 3.1) is handled by one small dense (m × m) contraction that the MXU
  would absorb for free.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against :mod:`ref` and real-TPU
behaviour is estimated analytically in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import hadamard_utils as hu

# Default token-block: (128 tokens × d lanes) f32 double-buffered stays well
# under a 16 MiB VMEM budget for every d used in this repo (d ≤ 2048:
# 128·2048·4·2 = 2 MiB).
DEFAULT_BLOCK_TOKENS = 128


def _butterfly(y: jnp.ndarray, p: int, m: int) -> jnp.ndarray:
    """log2(p) WHT butterfly stages over a (rows, p*m) block."""
    rows = y.shape[0]
    h = 1
    while h < p:
        y = y.reshape(rows, p // (2 * h), 2, h * m)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack((a + b, a - b), axis=2)
        h *= 2
    return y.reshape(rows, p * m)


def _wht_kernel(x_ref, o_ref, *, p: int, m: int):
    x = x_ref[...]
    y = _butterfly(x, p, m)
    o_ref[...] = y * (1.0 / np.sqrt(p))


def _wht_kernel_kron(x_ref, hm_ref, o_ref, *, p: int, m: int):
    x = x_ref[...]
    rows, d = x.shape
    y = x.reshape(rows, p, m)
    y = (y @ hm_ref[...]) * (1.0 / np.sqrt(m))
    y = y.reshape(rows, d)
    y = _butterfly(y, p, m)
    o_ref[...] = y * (1.0 / np.sqrt(p))


def wht(x: jnp.ndarray, block_tokens: int = DEFAULT_BLOCK_TOKENS) -> jnp.ndarray:
    """Orthonormal x @ H_d over the last axis of a 2-D (T, d) array."""
    t, d = x.shape
    p, m = hu.decompose_dim(d)
    bt = min(block_tokens, t)
    if t % bt != 0:  # pad to a whole number of blocks; cheap and trace-static
        pad = (-t) % bt
        return wht(jnp.pad(x, ((0, pad), (0, 0))), block_tokens=bt)[:t]
    if m == 1:
        kernel = functools.partial(_wht_kernel, p=p, m=m)
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
            grid=(t // bt,),
            in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
            interpret=True,
        )(x)
    hm = jnp.asarray(hu._KNOWN[m], dtype=x.dtype)
    kernel = functools.partial(_wht_kernel_kron, p=p, m=m)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        interpret=True,
    )(x, hm)


def wht_lastdim(x: jnp.ndarray, block_tokens: int = DEFAULT_BLOCK_TOKENS) -> jnp.ndarray:
    """x @ H over the last axis for arbitrary-rank x (reshapes to 2-D)."""
    shape = x.shape
    y = wht(x.reshape(-1, shape[-1]), block_tokens)
    return y.reshape(shape)


def had_headdim(x: jnp.ndarray) -> jnp.ndarray:
    """Head-wise online transform: (..., n_h, d_h) → each head @ H_{d_h}."""
    return wht_lastdim(x)


def had_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """*Hadamard heads* (Stage 1c): x @ (H_{n_h} ⊗ I_{d_h}) on (..., n_h·d_h).

    Implemented exactly as the paper suggests: reshape to expose the Kronecker
    structure, WHT over the head axis, reshape back.
    """
    d = x.shape[-1]
    dh = d // n_heads
    y = x.reshape(*x.shape[:-1], n_heads, dh)
    y = jnp.swapaxes(y, -1, -2)  # (..., d_h, n_h): heads become the lane axis
    y = wht_lastdim(y)
    y = jnp.swapaxes(y, -1, -2)
    return y.reshape(x.shape)
