"""Pallas on-line quantization kernels (QuaRot Stage 2b).

Two kernels:

* ``fake_quant`` — symmetric per-token quantize+dequantize of a linear-layer
  input.  This is the op QuaRot inserts in front of every weight matrix; in
  the paper it is a CUDA kernel that emits packed INT4 + row scales for the
  CUTLASS GEMM.  For accuracy graphs we keep the dequantized f32 (bit-identical
  to running the integer pipeline, see test_qmatmul.py), for the integer
  pipeline :func:`quant_int` emits codes + scales like the paper's kernel.
* ``kv_fake_quant`` — asymmetric group-wise quantize+dequantize used for the
  KV cache (paper: group 128 = head_dim, clip 0.95).

TPU adaptation: per-token reductions (amax / min / max) are row-wise over the
lane axis, which the VPU does natively; blocks are (block_tokens × d) VMEM
tiles, the same schedule as the Hadamard kernel so XLA can fuse the
(hadamard → quantize) pair that dominates the paper's overhead budget
(≤7 %, Fig. 7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_TOKENS = 128
_EPS = 1e-8


def _fake_quant_kernel(x_ref, lv_ref, clip_ref, o_ref):
    x = x_ref[...]
    levels = lv_ref[0].astype(x.dtype)
    clip = clip_ref[0].astype(x.dtype)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(amax * clip, _EPS) / jnp.maximum(levels, 1.0)
    q = jnp.clip(jnp.round(x / s), -levels, levels)
    o_ref[...] = jnp.where(levels > 0, q * s, x)


def fake_quant(x: jnp.ndarray, levels, clip,
               block_tokens: int = DEFAULT_BLOCK_TOKENS) -> jnp.ndarray:
    """Symmetric per-token fake quantization of a 2-D (T, d) activation.

    ``levels``/``clip`` are traced scalars (shape-(1,) f32) so a single lowered
    graph serves every bit-width; ``levels <= 0`` is a pass-through (FP16/A16
    sweeps).
    """
    t, d = x.shape
    bt = min(block_tokens, t)
    if t % bt != 0:
        pad = (-t) % bt
        return fake_quant(jnp.pad(x, ((0, pad), (0, 0))), levels, clip, bt)[:t]
    lv = jnp.asarray(levels, jnp.float32).reshape(1)
    cl = jnp.asarray(clip, jnp.float32).reshape(1)
    return pl.pallas_call(
        _fake_quant_kernel,
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        interpret=True,
    )(x, lv, cl)


def fake_quant_lastdim(x: jnp.ndarray, levels, clip) -> jnp.ndarray:
    """fake_quant for arbitrary-rank inputs (per-row == per-token on last axis)."""
    shape = x.shape
    return fake_quant(x.reshape(-1, shape[-1]), levels, clip).reshape(shape)


def _quant_int_kernel(x_ref, o_ref, s_ref, *, levels: float, clip: float):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(amax * clip, _EPS) / levels
    o_ref[...] = jnp.clip(jnp.round(x / s), -levels, levels).astype(jnp.int8)
    s_ref[...] = s


def quant_int(x: jnp.ndarray, levels: int, clip: float,
              block_tokens: int = DEFAULT_BLOCK_TOKENS):
    """Integer-emitting quantizer: (T, d) f32 → ((T, d) int8, (T, 1) f32 scale).

    This is the exact analogue of the paper's quantization kernel that feeds
    the CUTLASS INT4 GEMM — here it feeds the Pallas qmatmul kernel.
    """
    t, d = x.shape
    bt = min(block_tokens, t)
    if t % bt != 0:
        pad = (-t) % bt
        q, s = quant_int(jnp.pad(x, ((0, pad), (0, 0))), levels, clip, bt)
        return q[:t], s[:t]
    kernel = functools.partial(_quant_int_kernel, levels=float(levels), clip=clip)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t, d), jnp.int8),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
        ),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ),
        interpret=True,
    )(x)


def _kv_fake_quant_kernel(x_ref, o_ref, *, qmax: float, group: int, clip: float):
    x = x_ref[...]
    rows, d = x.shape
    g = x.reshape(rows, d // group, group)
    mx = jnp.max(g, axis=-1, keepdims=True)
    mn = jnp.min(g, axis=-1, keepdims=True)
    center = (mx + mn) * 0.5
    half = (mx - mn) * 0.5 * clip
    mn_c = center - half
    scale = jnp.maximum(2.0 * half, _EPS) / qmax
    q = jnp.clip(jnp.round((g - mn_c) / scale), 0.0, qmax)
    o_ref[...] = (q * scale + mn_c).reshape(rows, d)


def kv_fake_quant(x: jnp.ndarray, bits: int, group: int, clip: float,
                  block_tokens: int = DEFAULT_BLOCK_TOKENS) -> jnp.ndarray:
    """Asymmetric group-wise fake quantization over the last axis (KV cache)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    t, d = x2.shape
    bt = min(block_tokens, t)
    if t % bt != 0:
        pad = (-t) % bt
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        t = x2.shape[0]
    kernel = functools.partial(
        _kv_fake_quant_kernel, qmax=float(2**bits - 1), group=group, clip=clip)
    y = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        interpret=True,
    )(x2)
    return y[: x.reshape(-1, shape[-1]).shape[0]].reshape(shape)
