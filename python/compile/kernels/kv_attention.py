"""Pallas quantized-KV decode attention (QuaRot Stage 2c / Appendix A.10).

The paper's ``Decode`` routine loads INT4 KV segments, dequantizes them
in-register and runs an online-softmax (FlashAttention-style) accumulation
with the FP16 query.  Here each (batch, q-head) pair is one Pallas program;
the program streams the cached keys/values for its kv-head (GQA maps several
q-heads onto one kv-head through the BlockSpec index map), dequantizes with
the per-group asymmetric scales, folds in the current token's (not yet
cached) key/value, and normalizes once — numerically identical to softmax
over the concatenated scores.

TPU adaptation: the cache block for one program is (S, d_h) int8 + two
(S, d_h/group) f32 side tensors — at S=4096, d_h=128 that is 0.5 MiB + 32 KiB
in VMEM, far under budget; scores and the (d_h,) accumulator stay in
registers/VMEM.  ``interpret=True`` as everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kv_decode_kernel(q_ref, kc_ref, ks_ref, kz_ref, vc_ref, vs_ref, vz_ref,
                      kn_ref, vn_ref, len_ref, o_ref, *,
                      group: int, sm_scale: float):
    q = q_ref[0, 0, :]                     # (dh,)
    cur_len = len_ref[0]
    s, dh = kc_ref.shape[1], kc_ref.shape[3]
    ng = dh // group

    def deq(codes_ref, sc_ref, zp_ref):
        codes = codes_ref[0, :, 0, :].astype(jnp.float32)    # (S, dh)
        sc = sc_ref[0, :, 0, :]                              # (S, ng)
        zp = zp_ref[0, :, 0, :]
        g = codes.reshape(s, ng, group)
        return (g * sc[..., None] + zp[..., None]).reshape(s, dh)

    k = deq(kc_ref, ks_ref, kz_ref)
    v = deq(vc_ref, vs_ref, vz_ref)
    scores = (k @ q) * sm_scale                               # (S,)
    valid = jnp.arange(s) < cur_len
    scores = jnp.where(valid, scores, -jnp.inf)
    self_score = jnp.sum(kn_ref[0, 0, :] * q) * sm_scale      # current token
    m = jnp.maximum(jnp.max(scores), self_score)
    p = jnp.where(valid, jnp.exp(scores - m), 0.0)
    p_self = jnp.exp(self_score - m)
    denom = jnp.sum(p) + p_self
    out = (p @ v + p_self * vn_ref[0, 0, :]) / denom
    o_ref[0, 0, :] = out


def kv_decode_attention(q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
                        k_new, v_new, cur_len, *, group: int, sm_scale: float):
    """Single-token decode over a quantized cache.  Shapes as in ref.py:

    q (B,H,dh) f32 | {k,v}_codes (B,S,Hk,dh) int8 |
    {k,v}_{scale,zero} (B,S,Hk,dh/group) f32 | {k,v}_new (B,Hk,dh) f32 |
    cur_len (B,) int32 per-slot valid-cache lengths (each sequence in a
    continuous-batching decode batch sits at its own position); scalars
    broadcast.  Returns (B,H,dh) f32.
    """
    b, h, dh = q.shape
    _, s, hk, _ = k_codes.shape
    rep = h // hk
    ng = dh // group
    kernel = functools.partial(_kv_decode_kernel, group=group, sm_scale=sm_scale)
    ln = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))

    kv_spec = pl.BlockSpec((1, s, 1, dh), lambda bi, hi: (bi, 0, hi // rep, 0))
    sc_spec = pl.BlockSpec((1, s, 1, ng), lambda bi, hi: (bi, 0, hi // rep, 0))
    new_spec = pl.BlockSpec((1, 1, dh), lambda bi, hi: (bi, hi // rep, 0))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda bi, hi: (bi, hi, 0)),   # q
            kv_spec, sc_spec, sc_spec,                               # k
            kv_spec, sc_spec, sc_spec,                               # v
            new_spec, new_spec,                                      # k_new, v_new
            pl.BlockSpec((1,), lambda bi, hi: (bi,)),                # cur_len[b]
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda bi, hi: (bi, hi, 0)),
        interpret=True,
    )(q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero, k_new, v_new, ln)
