"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (pytest +
hypothesis) and double as the implementation used inside graphs when a
dimension falls outside a kernel's supported envelope.  They mirror the
quantization scheme of QuaRot Sec. 4 / Sec. 5 exactly:

* activations  — symmetric per-token INT-b, scale = clip * amax(row) / L
                 with L = 2^(b-1) - 1  (paper: clip 0.9, L = 7 for INT4)
* KV cache     — asymmetric per-group INT-b, scale = clip * (max-min) / (2^b-1)
                 (paper: clip 0.95, group 128 = head_dim)
* int matmul   — INT-b x INT-b with INT32 accumulation, dequantized by
                 row-scale x column-scale (paper Stage 2b).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import hadamard_utils as hu

_EPS = 1e-8


# --- Hadamard ----------------------------------------------------------------

def wht_rows(x: jnp.ndarray) -> jnp.ndarray:
    """x @ H_d over the last axis, via log2(d) butterfly stages.

    Supports d = 2^n * m for m in the known table (dense H_m on the odd part).
    Orthonormal (divides by sqrt(d) overall).
    """
    d = x.shape[-1]
    p, m = hu.decompose_dim(d)
    shape = x.shape
    # convention H_d = H_{2^n} (x) H_m: index i = i_pow2 * m + i_m
    y = x.reshape(*shape[:-1], p, m)
    if m > 1:
        hm = jnp.asarray(hu._KNOWN[m], dtype=x.dtype)  # un-normalized ±1
        # right-multiplying rows by H_m: row_vec @ H_m  ==  row_vec @ hm
        y = (y @ hm) * (1.0 / np.sqrt(m))
    h = 1
    while h < p:
        y = y.reshape(*shape[:-1], p // (2 * h), 2, h * m)
        a = y[..., 0, :]
        b = y[..., 1, :]
        y = jnp.stack((a + b, a - b), axis=-2)
        h *= 2
    y = y.reshape(shape)
    return y * (1.0 / np.sqrt(p))


def wht_dense(x: jnp.ndarray) -> jnp.ndarray:
    """Dense-matmul oracle: x @ H_d."""
    h = jnp.asarray(hu.hadamard_matrix(x.shape[-1]), dtype=x.dtype)
    return x @ h


def had_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """The paper's *Hadamard heads* block: x @ (H_{n_h} ⊗ I_{d_h}).

    x has last axis n_heads * head_dim; the transform mixes the head axis.
    """
    d = x.shape[-1]
    dh = d // n_heads
    y = x.reshape(*x.shape[:-1], n_heads, dh)
    y = jnp.moveaxis(y, -2, -1)  # (..., dh, n_heads)
    y = wht_rows(y)
    y = jnp.moveaxis(y, -1, -2)
    return y.reshape(x.shape)


def had_headdim(x: jnp.ndarray) -> jnp.ndarray:
    """Head-wise transform x_h @ H_{d_h} applied to (..., head_dim) tensors."""
    return wht_rows(x)


# --- activation quantization ---------------------------------------------------

def act_scale(x: jnp.ndarray, levels: jnp.ndarray, clip: jnp.ndarray) -> jnp.ndarray:
    """Per-token (per-row) symmetric scale: clip * amax / levels."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    return jnp.maximum(amax * clip, _EPS) / levels


def fake_quant_act(x: jnp.ndarray, levels, clip) -> jnp.ndarray:
    """Symmetric per-token fake quantization (quantize + dequantize).

    ``levels`` is the largest representable integer (7 for INT4, 31 for INT6,
    127 for INT8).  ``levels <= 0`` disables quantization (returns x) so one
    lowered graph can serve every precision sweep, including FP16/A16.
    """
    levels = jnp.asarray(levels, dtype=x.dtype)
    clip = jnp.asarray(clip, dtype=x.dtype)
    s = act_scale(x, jnp.maximum(levels, 1.0), clip)
    q = jnp.clip(jnp.round(x / s), -levels, levels)
    return jnp.where(levels > 0, q * s, x)


def quant_act_int(x: jnp.ndarray, levels: int, clip: float):
    """Integer-output variant: returns (int8 codes, per-row scale f32)."""
    s = act_scale(x, jnp.asarray(float(levels), x.dtype), jnp.asarray(clip, x.dtype))
    q = jnp.clip(jnp.round(x / s), -levels, levels).astype(jnp.int8)
    return q, s


# --- KV-cache (group-wise asymmetric) quantization ----------------------------

def kv_quant(x: jnp.ndarray, bits: int, group: int, clip: float):
    """Asymmetric group-wise quantization over the last axis.

    Returns (codes, scale, zero) with scale/zero shaped (..., d/group).
    Codes are stored *signed* (shifted by -2^(bits-1)) so any bits <= 8 fits
    an int8 buffer: stored = round((x - zero)/scale) - 2^(bits-1).
    Matches the paper's KV scheme (clip 0.95, group = head_dim); clipping
    shrinks the range symmetrically about its center.
    """
    shape = x.shape
    g = x.reshape(*shape[:-1], shape[-1] // group, group)
    mx = jnp.max(g, axis=-1, keepdims=True)
    mn = jnp.min(g, axis=-1, keepdims=True)
    center = (mx + mn) * 0.5
    half = (mx - mn) * 0.5 * clip
    mn_c = center - half
    qmax = float(2**bits - 1)
    offset = float(2 ** (bits - 1))
    scale = jnp.maximum(2.0 * half, _EPS) / qmax
    q = jnp.clip(jnp.round((g - mn_c) / scale), 0.0, qmax) - offset
    return (
        q.astype(jnp.int8).reshape(shape),
        scale.squeeze(-1),
        # fold the signed shift into the zero-point: x ≈ code*scale + zero
        (mn_c + offset * scale).squeeze(-1),
    )


def kv_dequant(q: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray, group: int):
    """Inverse of kv_quant: codes * scale + zero, group-wise."""
    shape = q.shape
    g = q.astype(scale.dtype).reshape(*shape[:-1], shape[-1] // group, group)
    x = g * scale[..., None] + zero[..., None]
    return x.reshape(shape)


def kv_fake_quant(x: jnp.ndarray, bits: int, group: int, clip: float):
    q, s, z = kv_quant(x, bits, group, clip)
    return kv_dequant(q, s, z, group)


# --- quantized matmul ----------------------------------------------------------

def qmatmul(x: jnp.ndarray, w_int: jnp.ndarray, w_scale: jnp.ndarray,
            levels: int = 7, clip: float = 0.9) -> jnp.ndarray:
    """INT-b GEMM oracle: per-token quantize x, integer matmul, dequantize.

    x: (T, K) f32;  w_int: (K, N) int8 codes in [-levels, levels];
    w_scale: (N,) per-column f32.  Output (T, N) f32.
    """
    xq, xs = quant_act_int(x, levels, clip)
    acc = jnp.matmul(xq.astype(jnp.int32), w_int.astype(jnp.int32))
    return acc.astype(x.dtype) * xs * w_scale[None, :]


# --- quantized-KV attention decode ----------------------------------------------

def kv_decode_attention(q, k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
                        k_new, v_new, cur_len, *, group: int, sm_scale: float):
    """Single-token decode attention over a quantized cache + current token.

    q:        (B, H, dh) f32 — current query (FP16-equivalent, paper Stage 2c)
    k_codes:  (B, S, Hk, dh) int8 codes; k_scale/k_zero: (B, S, Hk, dh/group)
    v_*:      same layout as k_*
    k_new/v_new: (B, Hk, dh) f32 — current token's key/value (attends to self)
    cur_len:  (B,) int32 (scalars broadcast) — valid cached positions (<= S)
    Supports GQA: H q-heads share Hk kv-heads (H % Hk == 0).
    """
    B, S, Hk, dh = k_codes.shape
    H = q.shape[1]
    rep = H // Hk
    k = kv_dequant(k_codes, k_scale, k_zero, group)  # (B,S,Hk,dh)
    v = kv_dequant(v_codes, v_scale, v_zero, group)
    k = jnp.repeat(k, rep, axis=2)  # (B,S,H,dh)
    v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q, k) * sm_scale
    lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    mask = jnp.arange(S)[None, None, :] < lens[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    self_scores = jnp.einsum("bhd,bhd->bh", q, jnp.repeat(k_new, rep, axis=1)) * sm_scale
    all_scores = jnp.concatenate([scores, self_scores[..., None]], axis=-1)
    p = jax.nn.softmax(all_scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p[..., :S], v)
    out = out + p[..., S, None] * jnp.repeat(v_new, rep, axis=1)
    return out
