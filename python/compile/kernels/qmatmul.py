"""Pallas INT-b GEMM with INT32 accumulation (QuaRot's CUTLASS kernel analogue).

The paper's 4-bit linear layer is: quantize the FP16 activation per token,
run an INT4×INT4 CUTLASS TensorCore GEMM into an INT32 accumulator, then
dequantize by row-scale × column-scale back to FP16 (Sec. 5.2, Fig. 7).

TPU adaptation (DESIGN.md §2): the TensorCore WMMA tile becomes an MXU-shaped
matmul over (block_m × block_k) activation and (block_k × block_n) weight
tiles; the HBM↔VMEM schedule the CUDA kernel expressed with threadblocks is a
3-D Pallas grid with the K axis innermost and an INT32 VMEM accumulator that
lives across K steps.  ``interpret=True`` (CPU) — MXU utilization for the
chosen tiles is estimated in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant as qk

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _qmm_kernel(xq_ref, w_ref, o_ref, *, nk: int):
    """One (m, n, k) grid step: INT32 accumulate; epilogue left to caller."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        xq_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def qmatmul_int(xq: jnp.ndarray, w_int: jnp.ndarray,
                bm: int = DEFAULT_BLOCK_M, bn: int = DEFAULT_BLOCK_N,
                bk: int = DEFAULT_BLOCK_K) -> jnp.ndarray:
    """(T, K) int8 × (K, N) int8 → (T, N) int32 via tiled Pallas GEMM."""
    t, k = xq.shape
    k2, n = w_int.shape
    assert k == k2, (xq.shape, w_int.shape)
    bm, bn, bk = min(bm, t), min(bn, n), min(bk, k)
    if t % bm or n % bn or k % bk:
        # Pad to whole tiles; zero rows/cols contribute nothing to the GEMM.
        pt, pn, pk = (-t) % bm, (-n) % bn, (-k) % bk
        acc = qmatmul_int(
            jnp.pad(xq, ((0, pt), (0, pk))), jnp.pad(w_int, ((0, pk), (0, pn))),
            bm, bn, bk)
        return acc[:t, :n]
    kernel = functools.partial(_qmm_kernel, nk=k // bk)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.int32),
        grid=(t // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(xq, w_int)


def qmatmul(x: jnp.ndarray, w_int: jnp.ndarray, w_scale: jnp.ndarray,
            levels: int = 7, clip: float = 0.9) -> jnp.ndarray:
    """Full quantized linear layer: quantize → INT GEMM → dequantize.

    x: (T, K) f32; w_int: (K, N) int8 codes; w_scale: (N,) f32 per column.
    Composes the quantization kernel and the GEMM kernel exactly like the
    paper composes its quantization kernel with CUTLASS.
    """
    xq, xs = qk.quant_int(x, levels, clip)
    acc = qmatmul_int(xq, w_int)
    return acc.astype(x.dtype) * xs * w_scale[None, :]
