"""Model configurations.

The paper evaluates LLAMA-2 {7B, 13B, 70B} (+ LLAMA-3 and Phi-3 in the
appendix).  Those checkpoints are unavailable here (DESIGN.md §1), so each is
proxied by a tiny LLaMA-*architecture* model trained at artifact-build time:

* ``tiny-mha``   — the LLAMA2-7B proxy (MHA, pow-2 dims, fast-path Hadamards)
* ``small-mha``  — the LLAMA2-13B proxy; d_ff = 1536 = 2^7·12 exercises the
                   Kronecker H_12 construction the paper needs for LLaMA's
                   non-pow-2 FFN sizes (11008, 13824, ...)
* ``tiny-gqa``   — the LLAMA2-70B proxy: grouped-query attention, which is
                   what gives the 70B its distinct KV-memory behaviour
* ``phi-proxy``  — the Phi-3-mini stand-in for Appendix A.9

All dims keep n_heads and head_dim powers of two, which the paper requires
for the Hadamard-heads identity (eq. 9).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    max_seq: int            # prefill sequence length (static in the graphs)
    cache_seq: int          # decode KV-cache capacity (static in the graphs)
    decode_batch: int       # decode graph batch (serving slots)
    rope_theta: float = 10000.0
    kv_group: int = 0       # 0 → head_dim (the paper's group 128 == d_head)
    # outlier-inducing recipe (DESIGN.md §1): a few embedding channels are
    # initialized hot so the residual stream develops the outlier features
    # QuaRot exists to remove.  Purely a property of the synthetic checkpoint.
    outlier_channels: int = 4
    outlier_scale: float = 8.0
    # training
    train_steps: int = 250
    train_batch: int = 16
    train_seq: int = 128
    lr: float = 2e-3

    def __post_init__(self):
        assert self.d_model == self.n_heads * self.d_head
        assert self.n_heads % self.n_kv_heads == 0
        assert self.n_heads & (self.n_heads - 1) == 0, "eq. (9) needs pow-2 heads"
        assert self.d_head & (self.d_head - 1) == 0, "eq. (9) needs pow-2 head dim"

    @property
    def group(self) -> int:
        return self.kv_group or self.d_head

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    def param_count(self) -> int:
        per_layer = (
            self.d_model * self.d_attn          # wq
            + 2 * self.d_model * self.d_kv      # wk, wv
            + self.d_attn * self.d_model        # wo
            + 2 * self.d_model * self.d_ff      # wup, wgate
            + self.d_ff * self.d_model          # wdown
            + 2 * self.d_model                  # norms
        )
        return (
            self.n_layers * per_layer
            + 2 * self.vocab * self.d_model     # embed + head
            + self.d_model                      # final norm
        )


CONFIGS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        ModelConfig(
            name="tiny-mha", vocab=512, d_model=256, n_layers=4,
            n_heads=8, n_kv_heads=8, d_head=32, d_ff=1024,
            max_seq=128, cache_seq=256, decode_batch=8,
            train_steps=250,
        ),
        ModelConfig(
            name="small-mha", vocab=512, d_model=512, n_layers=6,
            n_heads=8, n_kv_heads=8, d_head=64, d_ff=1536,
            max_seq=128, cache_seq=256, decode_batch=8,
            train_steps=140,
        ),
        ModelConfig(
            name="tiny-gqa", vocab=512, d_model=256, n_layers=4,
            n_heads=8, n_kv_heads=2, d_head=32, d_ff=1024,
            max_seq=128, cache_seq=256, decode_batch=8,
            train_steps=250,
        ),
        ModelConfig(
            name="phi-proxy", vocab=512, d_model=128, n_layers=2,
            n_heads=4, n_kv_heads=4, d_head=32, d_ff=512,
            max_seq=128, cache_seq=256, decode_batch=8,
            train_steps=120,
        ),
    ]
}

# Which configs `make artifacts` builds by default.  All benches run on these.
DEFAULT_BUILD = ["tiny-mha", "small-mha", "tiny-gqa", "phi-proxy"]
