"""Binary artifact formats shared with the rust side (rust/src/model/io.rs).

Everything is little-endian, versioned and magic-tagged.  Three containers:

* ``weights.bin``  ("QWTS") — named tensor archive (f32 / i8 / i32).
* ``corpus.bin``   ("QCRP") — token splits (train/calib/eval) as u16 streams.
* ``probes.bin``   ("QPRB") — the six zero-shot probe tasks (Table 2 proxy):
  multiple-choice items with a context, N candidate continuations and a gold
  index; n_choices == 0 marks a LAMBADA-style exact-next-token task.

Kept deliberately dumb so the rust parser is ~100 lines with no deps.
"""

from __future__ import annotations

import struct

import numpy as np

_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1, np.dtype(np.int32): 2}


def write_weights(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(b"QWTS")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _DTYPES[arr.dtype]
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_weights(path: str) -> dict[str, np.ndarray]:
    inv = {v: k for k, v in _DTYPES.items()}
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"QWTS"
        _, n = struct.unpack("<II", f.read(8))
        for _ in range(n):
            (ln,) = struct.unpack("<H", f.read(2))
            name = f.read(ln).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            shape = tuple(struct.unpack("<I", f.read(4))[0] for _ in range(ndim))
            (nb,) = struct.unpack("<Q", f.read(8))
            out[name] = np.frombuffer(f.read(nb), dtype=inv[code]).reshape(shape)
    return out


def write_corpus(path: str, vocab: int, splits: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(b"QCRP")
        f.write(struct.pack("<III", 1, vocab, len(splits)))
        for name, toks in splits.items():
            toks = np.asarray(toks, np.uint16)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", len(toks)))
            f.write(toks.tobytes())


def read_corpus(path: str) -> tuple[int, dict[str, np.ndarray]]:
    with open(path, "rb") as f:
        assert f.read(4) == b"QCRP"
        _, vocab, n = struct.unpack("<III", f.read(12))
        splits = {}
        for _ in range(n):
            (ln,) = struct.unpack("<H", f.read(2))
            name = f.read(ln).decode()
            (cnt,) = struct.unpack("<I", f.read(4))
            splits[name] = np.frombuffer(f.read(2 * cnt), dtype=np.uint16)
        return vocab, splits


def write_probes(path: str, tasks: list[dict]) -> None:
    """tasks: [{name, items: [{ctx: u16[], choices: [u16[]], gold: int}]}].

    ``choices == []`` with ``gold_token`` set marks an exact-next-token item.
    """
    with open(path, "wb") as f:
        f.write(b"QPRB")
        f.write(struct.pack("<II", 1, len(tasks)))
        for t in tasks:
            nb = t["name"].encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", len(t["items"])))
            for it in t["items"]:
                ctx = np.asarray(it["ctx"], np.uint16)
                choices = it.get("choices", [])
                f.write(struct.pack("<HB", len(ctx), len(choices)))
                f.write(ctx.tobytes())
                if choices:
                    f.write(struct.pack("<B", it["gold"]))
                    for ch in choices:
                        ch = np.asarray(ch, np.uint16)
                        f.write(struct.pack("<H", len(ch)))
                        f.write(ch.tobytes())
                else:
                    f.write(struct.pack("<H", it["gold_token"]))


def read_probes(path: str) -> list[dict]:
    with open(path, "rb") as f:
        assert f.read(4) == b"QPRB"
        _, n = struct.unpack("<II", f.read(8))
        tasks = []
        for _ in range(n):
            (ln,) = struct.unpack("<H", f.read(2))
            name = f.read(ln).decode()
            (cnt,) = struct.unpack("<I", f.read(4))
            items = []
            for _ in range(cnt):
                cl, nch = struct.unpack("<HB", f.read(3))
                ctx = np.frombuffer(f.read(2 * cl), dtype=np.uint16)
                if nch:
                    (gold,) = struct.unpack("<B", f.read(1))
                    choices = []
                    for _ in range(nch):
                        (chl,) = struct.unpack("<H", f.read(2))
                        choices.append(np.frombuffer(f.read(2 * chl), dtype=np.uint16))
                    items.append({"ctx": ctx, "choices": choices, "gold": gold})
                else:
                    (gt,) = struct.unpack("<H", f.read(2))
                    items.append({"ctx": ctx, "choices": [], "gold_token": gt})
            tasks.append({"name": name, "items": items})
        return tasks
