"""QuaRot Stage 1: fuse rotations into the weights (computational invariance).

Implements Sec. 4 Stages 1a-1d of the paper on the parameter pytree of
:mod:`model`.  All arithmetic is done in float64 and cast back to f32 so the
rotated model matches the original to f32 round-off — the property the
invariance tests pin down.

Row-vector convention (matches model.py): activations are rows, a linear
layer is ``y = x @ W`` with W shaped (in, out).

Stage 1a  residual rotation Q (randomized Hadamard of size d_model):
    - RMSNorm scales α are absorbed into every *input-side* weight first
      (the commutation property, eq. 3, needs scale-free norms), including
      the final norm into the LM head.
    - embed ← embed @ Q;  W_in ← Qᵀ diag(α) W_in;  W_out ← W_out @ Q.
Stage 1b  FFN online Hadamard: W_down ← H_dff @ W_down (graph inserts
    act ← act @ H_dff before the quantizer).
Stage 1c  value path: W_v ← W_v (I ⊗ H_dh);  W_o ← (I ⊗ H_dh)ᵀ
    (H_nh ⊗ I)ᵀ W_o — together with the graph's online *Hadamard heads*
    (z ← z (H_nh ⊗ I)) attention output is fully H-rotated and undone
    inside W_o.  GQA: the per-head H_dh on the n_kv value heads carries to
    all n_q attention-output heads.
Stage 1d  keys/queries rotate *online* after RoPE (post-RoPE caching);
    nothing to fuse — handled entirely in the graph.

``rotate_params`` also supports a generic orthogonal Q (Table 8's random-
orthogonal ablation) — the online ops stay Hadamard, exactly like the paper.
"""

from __future__ import annotations

import numpy as np

from . import hadamard_utils as hu
from .configs import ModelConfig


def fuse_norms(params: dict) -> dict:
    """Absorb RMSNorm scales into adjacent input-side weights (Stage 1a prep).

    Returns a new pytree where every *_norm is all-ones and wq/wk/wv/wup/
    wgate/lm_head carry diag(α) on their input side.
    """
    p = {k: np.array(v, np.float64) for k, v in params.items()}  # deep copies
    L = p["attn_norm"].shape[0]
    for l in range(L):
        a = p["attn_norm"][l][:, None]
        p["wq"][l] = a * p["wq"][l]
        p["wk"][l] = a * p["wk"][l]
        p["wv"][l] = a * p["wv"][l]
        f = p["ffn_norm"][l][:, None]
        p["wup"][l] = f * p["wup"][l]
        p["wgate"][l] = f * p["wgate"][l]
    p["lm_head"] = p["final_norm"][:, None] * p["lm_head"]
    p["attn_norm"] = np.ones_like(p["attn_norm"])
    p["ffn_norm"] = np.ones_like(p["ffn_norm"])
    p["final_norm"] = np.ones_like(p["final_norm"])
    return p


def rotate_params(cfg: ModelConfig, params: dict, *, seed: int = 0,
                  q_matrix: np.ndarray | None = None) -> dict:
    """Full Stage-1 transform.  Input: *unfused* trained params.

    q_matrix overrides the residual rotation (Table 8 uses a QR-of-Gaussian
    matrix); default is the randomized Hadamard the paper recommends.
    """
    d, dff, dh = cfg.d_model, cfg.d_ff, cfg.d_head
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    p = fuse_norms(params)

    Q = np.asarray(q_matrix, np.float64) if q_matrix is not None \
        else hu.randomized_hadamard(d, seed)
    H_ff = hu.hadamard_matrix(dff)
    H_dh = hu.hadamard_matrix(dh)
    H_nh = hu.hadamard_matrix(nh)
    # online Hadamard-heads block in the graph: z ← z (H_nh ⊗ I_dh)
    K_heads = np.kron(H_nh, np.eye(dh))

    out = dict(p)
    # Stage 1a — residual stream
    out["embed"] = p["embed"] @ Q
    out["lm_head"] = Q.T @ p["lm_head"]
    L = cfg.n_layers
    for l in range(L):
        for k in ("wq", "wk", "wv", "wup", "wgate"):
            out[k][l] = Q.T @ p[k][l]          # input side
        out["wo"][l] = p["wo"][l] @ Q          # output side
        out["wdown"][l] = p["wdown"][l] @ Q

        # Stage 1c — value path, per kv-head H_dh on W_v's output columns
        wv = out["wv"][l].reshape(d, nkv, dh)
        out["wv"][l] = (wv @ H_dh).reshape(d, nkv * dh)
        # W_o input side: undo (I⊗H_dh) then undo the online (H_nh⊗I):
        # z_final = z (I⊗H_dh)(H_nh⊗I) ⇒ W_o ← (H_nh⊗I)ᵀ (I⊗H_dh)ᵀ W_o
        wo = out["wo"][l].reshape(nh, dh, d)
        wo = np.einsum("ij,hjd->hid", H_dh.T, wo)      # (I⊗H_dh)ᵀ on input
        wo = wo.reshape(nh * dh, d)
        out["wo"][l] = K_heads.T @ wo                   # (H_nh⊗I)ᵀ on input

        # Stage 1b — FFN: undo the online H_dff inside W_down
        out["wdown"][l] = H_ff.T @ out["wdown"][l]

    return {k: np.asarray(v, np.float32) for k, v in out.items()}


def incoherence(x: np.ndarray) -> float:
    """μ-incoherence of a matrix (eq. 2): max|x| / (||x||_F / sqrt(mn))."""
    x = np.asarray(x, np.float64)
    rms = np.linalg.norm(x) / np.sqrt(x.size)
    return float(np.abs(x).max() / max(rms, 1e-12))


def activation_outlier_stats(acts: np.ndarray) -> dict:
    """Fig. 1 statistics: per-channel max |x|, kurtosis, incoherence."""
    a = np.asarray(acts, np.float64).reshape(-1, acts.shape[-1])
    ch_max = np.abs(a).max(axis=0)
    mu, sd = a.mean(), a.std()
    kurt = float(np.mean(((a - mu) / max(sd, 1e-12)) ** 4))
    return {
        "channel_absmax": ch_max,
        "max_over_median_channel": float(ch_max.max() / max(np.median(ch_max), 1e-12)),
        "kurtosis": kurt,
        "incoherence": incoherence(a),
    }
