"""Pallas WHT kernel vs dense oracle + Hadamard-matrix invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import hadamard_utils as hu
from compile.kernels import hadamard as hk
from compile.kernels import ref

DIMS = [2, 4, 8, 12, 16, 20, 24, 32, 48, 64, 128, 256, 320, 1536]


@pytest.mark.parametrize("d", DIMS)
def test_hadamard_matrix_orthonormal(d):
    h = hu.hadamard_matrix(d)
    assert np.abs(h @ h.T - np.eye(d)).max() < 1e-10


@pytest.mark.parametrize("d", [16, 64, 256])
def test_randomized_hadamard_orthonormal(d):
    q = hu.randomized_hadamard(d, seed=7)
    assert np.abs(q @ q.T - np.eye(d)).max() < 1e-10


@pytest.mark.parametrize("d", [16, 64, 128])
def test_random_orthogonal(d):
    q = hu.random_orthogonal(d, seed=3)
    assert np.abs(q @ q.T - np.eye(d)).max() < 1e-10


@pytest.mark.parametrize("d", DIMS)
def test_ref_wht_matches_dense(d):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((5, d)).astype(np.float32)
    got = np.asarray(ref.wht_rows(jnp.asarray(x)))
    want = x @ hu.hadamard_matrix(d, dtype=np.float32)
    np.testing.assert_allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("d", [8, 12, 24, 64, 256, 1536])
@pytest.mark.parametrize("t", [1, 3, 128, 130])
def test_kernel_wht_matches_ref(d, t):
    rng = np.random.default_rng(1)
    x = rng.standard_normal((t, d)).astype(np.float32)
    got = np.asarray(hk.wht(jnp.asarray(x)))
    want = np.asarray(ref.wht_rows(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_wht_involution_pow2():
    # H is symmetric for pure Sylvester, so applying twice is the identity.
    rng = np.random.default_rng(2)
    x = rng.standard_normal((17, 64)).astype(np.float32)
    y = np.asarray(hk.wht(hk.wht(jnp.asarray(x))))
    np.testing.assert_allclose(y, x, atol=1e-4)


def test_had_heads_kronecker_identity():
    """Paper eq. (9): (I ⊗ H_dh)(H_nh ⊗ I) == H_{nh·dh} for powers of two."""
    nh, dh = 8, 32
    d = nh * dh
    rng = np.random.default_rng(3)
    x = rng.standard_normal((4, d)).astype(np.float32)
    xj = jnp.asarray(x)
    # apply (I ⊗ H_dh): per-head transform
    step1 = np.asarray(
        ref.had_headdim(xj.reshape(4, nh, dh)).reshape(4, d))
    step2 = np.asarray(ref.had_heads(jnp.asarray(step1), nh))
    full = x @ hu.hadamard_matrix(d, dtype=np.float32)
    np.testing.assert_allclose(step2, full, atol=1e-3)


def test_kernel_had_heads_matches_ref():
    nh, dh = 8, 32
    rng = np.random.default_rng(4)
    x = rng.standard_normal((6, nh * dh)).astype(np.float32)
    got = np.asarray(hk.had_heads(jnp.asarray(x), nh))
    want = np.asarray(ref.had_heads(jnp.asarray(x), nh))
    np.testing.assert_allclose(got, want, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    logd=st.integers(min_value=1, max_value=8),
    t=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_wht_property(logd, t, seed):
    """Hypothesis sweep: kernel == dense oracle, norm preserved."""
    d = 2**logd
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((t, d)).astype(np.float32)
    got = np.asarray(hk.wht(jnp.asarray(x)))
    want = x @ hu.hadamard_matrix(d, dtype=np.float32)
    np.testing.assert_allclose(got, want, atol=1e-3)
    np.testing.assert_allclose(
        np.linalg.norm(got, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-3)
