"""Quantized-KV decode attention kernel vs oracle and vs exact attention."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import kv_attention as kva
from compile.kernels import ref


def _setup(b, s, h, hk, dh, cur_len, bits=4, group=None, seed=0, clip=0.95):
    group = group or dh
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((b, h, dh)).astype(np.float32)
    k = rng.standard_normal((b, s, hk, dh)).astype(np.float32)
    v = rng.standard_normal((b, s, hk, dh)).astype(np.float32)
    k_new = rng.standard_normal((b, hk, dh)).astype(np.float32)
    v_new = rng.standard_normal((b, hk, dh)).astype(np.float32)
    kc, ks, kz = ref.kv_quant(jnp.asarray(k), bits, group, clip)
    vc, vs, vz = ref.kv_quant(jnp.asarray(v), bits, group, clip)
    args = (jnp.asarray(q), kc, ks, kz, vc, vs, vz,
            jnp.asarray(k_new), jnp.asarray(v_new), cur_len)
    return args, (q, k, v, k_new, v_new), group


@pytest.mark.parametrize("b,s,h,hk,dh,cur_len", [
    (1, 8, 2, 2, 16, 5),
    (2, 16, 4, 4, 32, 16),
    (2, 16, 8, 2, 32, 9),    # GQA 4:1
    (1, 32, 4, 1, 16, 1),    # MQA, single valid cache slot
])
def test_kernel_matches_ref(b, s, h, hk, dh, cur_len):
    args, _, group = _setup(b, s, h, hk, dh, cur_len)
    sm = 1.0 / np.sqrt(dh)
    got = np.asarray(kva.kv_decode_attention(*args, group=group, sm_scale=sm))
    want = np.asarray(ref.kv_decode_attention(*args, group=group, sm_scale=sm))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matches_exact_attention_at_high_bits():
    """With 8-bit KV the kernel must track exact f32 attention closely."""
    b, s, h, hk, dh, cur_len = 2, 12, 4, 4, 32, 12
    args, (q, k, v, k_new, v_new), group = _setup(b, s, h, hk, dh, cur_len,
                                                  bits=8, clip=1.0)
    sm = 1.0 / np.sqrt(dh)
    got = np.asarray(kva.kv_decode_attention(*args, group=group, sm_scale=sm))

    # exact reference: concat cache + current token, plain softmax attention
    kk = np.concatenate([k, k_new[:, None]], axis=1)  # (b, s+1, hk, dh)
    vv = np.concatenate([v, v_new[:, None]], axis=1)
    scores = np.einsum("bhd,bshd->bhs", q, kk) * sm
    p = jax.nn.softmax(jnp.asarray(scores), axis=-1)
    want = np.einsum("bhs,bshd->bhd", np.asarray(p), vv)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_masking_ignores_stale_cache():
    """Entries beyond cur_len must not affect the output."""
    b, s, h, hk, dh = 1, 16, 2, 2, 16
    args1, _, group = _setup(b, s, h, hk, dh, cur_len=4, seed=1)
    # poison the cache beyond cur_len
    kc = np.asarray(args1[1]).copy()
    kc[:, 4:] = 7
    vc = np.asarray(args1[4]).copy()
    vc[:, 4:] = 15
    args2 = list(args1)
    args2[1] = jnp.asarray(kc)
    args2[4] = jnp.asarray(vc)
    sm = 1.0 / np.sqrt(dh)
    out1 = np.asarray(kva.kv_decode_attention(*args1, group=group, sm_scale=sm))
    out2 = np.asarray(kva.kv_decode_attention(*args2, group=group, sm_scale=sm))
    np.testing.assert_allclose(out1, out2, atol=1e-6)


def test_zero_len_cache_attends_only_to_self():
    b, s, h, hk, dh = 1, 8, 2, 2, 16
    args, (_, _, _, _, v_new), group = _setup(b, s, h, hk, dh, cur_len=0, seed=2)
    sm = 1.0 / np.sqrt(dh)
    out = np.asarray(kva.kv_decode_attention(*args, group=group, sm_scale=sm))
    want = np.repeat(v_new, h // hk, axis=1)
    np.testing.assert_allclose(out, want, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.sampled_from([8, 16, 32]),
    hk=st.sampled_from([1, 2, 4]),
    rep=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([16, 32]),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_property(b, s, hk, rep, dh, bits, seed):
    h = hk * rep
    cur_len = int(seed % (s + 1))
    args, _, group = _setup(b, s, h, hk, dh, cur_len, bits=bits, seed=seed)
    sm = 1.0 / np.sqrt(dh)
    got = np.asarray(kva.kv_decode_attention(*args, group=group, sm_scale=sm))
    want = np.asarray(ref.kv_decode_attention(*args, group=group, sm_scale=sm))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    assert np.isfinite(got).all()
