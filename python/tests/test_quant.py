"""Quantization kernels vs oracles + scheme invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import quant as qk
from compile.kernels import ref


@pytest.mark.parametrize("levels", [7, 31, 127])
@pytest.mark.parametrize("t,d", [(1, 16), (7, 64), (128, 256), (130, 32)])
def test_fake_quant_kernel_matches_ref(levels, t, d):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((t, d)).astype(np.float32) * 3.0
    got = np.asarray(qk.fake_quant(jnp.asarray(x), float(levels), 0.9))
    want = np.asarray(ref.fake_quant_act(jnp.asarray(x), float(levels), 0.9))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_fake_quant_passthrough_when_disabled():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((9, 32)).astype(np.float32)
    got = np.asarray(qk.fake_quant(jnp.asarray(x), 0.0, 0.9))
    np.testing.assert_allclose(got, x)


def test_fake_quant_error_bound():
    """|deq(q(x)) - x| <= scale/2 for values inside the clip range."""
    rng = np.random.default_rng(2)
    x = rng.standard_normal((50, 64)).astype(np.float32)
    levels, clip = 7.0, 1.0  # clip=1: no clipping, bound is exact
    y = np.asarray(ref.fake_quant_act(jnp.asarray(x), levels, clip))
    amax = np.abs(x).max(axis=-1, keepdims=True)
    scale = amax / levels
    assert (np.abs(y - x) <= scale / 2 + 1e-6).all()


def test_quant_int_roundtrip_matches_fake_quant():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((33, 48)).astype(np.float32)
    q, s = qk.quant_int(jnp.asarray(x), 7, 0.9)
    deq = np.asarray(q).astype(np.float32) * np.asarray(s)
    want = np.asarray(ref.fake_quant_act(jnp.asarray(x), 7.0, 0.9))
    np.testing.assert_allclose(deq, want, atol=1e-6)
    assert np.asarray(q).min() >= -7 and np.asarray(q).max() <= 7


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("group", [8, 16, 32])
def test_kv_quant_roundtrip_bound(bits, group):
    """Group-wise asymmetric round-trip stays within half a step (clip=1)."""
    rng = np.random.default_rng(4)
    x = rng.standard_normal((6, 5, group * 2)).astype(np.float32)
    y = np.asarray(ref.kv_fake_quant(jnp.asarray(x), bits, group, 1.0))
    g = x.reshape(-1, group)
    step = (g.max(-1) - g.min(-1)) / (2**bits - 1)
    err = np.abs(y.reshape(-1, group) - g).max(-1)
    assert (err <= step / 2 + 1e-5).all()


def test_kv_quant_kernel_matches_ref():
    rng = np.random.default_rng(5)
    x = rng.standard_normal((4, 10, 64)).astype(np.float32)
    got = np.asarray(qk.kv_fake_quant(jnp.asarray(x), 4, 32, 0.95))
    want = np.asarray(ref.kv_fake_quant(jnp.asarray(x), 4, 32, 0.95))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_kv_quant_codes_in_range():
    rng = np.random.default_rng(6)
    x = rng.standard_normal((20, 32)).astype(np.float32)
    for bits in (2, 3, 4, 8):
        q, s, z = ref.kv_quant(jnp.asarray(x), bits, 16, 0.95)
        qn = np.asarray(q)  # signed storage: [-2^(b-1), 2^(b-1)-1]
        assert qn.min() >= -(2 ** (bits - 1)) and qn.max() <= 2 ** (bits - 1) - 1


def test_kv_quant_constant_group_exact():
    """A constant group must round-trip exactly (degenerate range)."""
    x = jnp.full((2, 16), 1.234, dtype=jnp.float32)
    y = np.asarray(ref.kv_fake_quant(x, 4, 16, 0.95))
    np.testing.assert_allclose(y, 1.234, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    t=st.integers(1, 50),
    logd=st.integers(2, 7),
    levels=st.sampled_from([1, 3, 7, 15, 31, 127]),
    clip=st.floats(0.5, 1.0),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_fake_quant_property(t, logd, levels, clip, seed, scale):
    """Hypothesis: kernel==oracle across shapes/levels/clips/magnitudes,
    output codes lie on the quantization grid."""
    d = 2**logd
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((t, d)) * scale).astype(np.float32)
    got = np.asarray(qk.fake_quant(jnp.asarray(x), float(levels), clip))
    want = np.asarray(ref.fake_quant_act(jnp.asarray(x), float(levels), clip))
    np.testing.assert_allclose(got, want, atol=1e-6)
    # grid check: y / s must be integers
    amax = np.abs(x).max(axis=-1, keepdims=True)
    s = np.maximum(amax * clip, 1e-8) / levels
    ratio = got / s
    np.testing.assert_allclose(ratio, np.round(ratio), atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    groups=st.integers(1, 4),
    rows=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_kv_quant_property(bits, groups, rows, seed):
    group = 16
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, groups * group)).astype(np.float32)
    q, s, z = ref.kv_quant(jnp.asarray(x), bits, group, 0.95)
    y = np.asarray(ref.kv_dequant(q, s, z, group))
    # dequantized values stay within the (clipped) group range
    g = x.reshape(rows, groups, group)
    lo = g.min(-1) - (g.max(-1) - g.min(-1)) * 0.05
    hi = g.max(-1) + (g.max(-1) - g.min(-1)) * 0.05
    yg = y.reshape(rows, groups, group)
    assert (yg >= lo[..., None] - 1e-5).all() and (yg <= hi[..., None] + 1e-5).all()
