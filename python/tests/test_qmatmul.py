"""Quantized-GEMM kernel vs oracle, and the fake-quant == integer-pipeline
equivalence that justifies evaluating accuracy with fake-quant graphs."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import qmatmul as qmm
from compile.kernels import ref


def _quant_weight(w: np.ndarray, levels: int):
    """Per-column symmetric RTN (no clipping) — mirrors rust quant::rtn."""
    s = np.maximum(np.abs(w).max(axis=0), 1e-8) / levels
    wq = np.clip(np.round(w / s[None, :]), -levels, levels).astype(np.int8)
    return wq, s.astype(np.float32)


@pytest.mark.parametrize("t,k,n", [(4, 16, 8), (128, 128, 128), (130, 96, 72)])
def test_qmatmul_int_matches_numpy(t, k, n):
    rng = np.random.default_rng(0)
    xq = rng.integers(-7, 8, size=(t, k)).astype(np.int8)
    wq = rng.integers(-7, 8, size=(k, n)).astype(np.int8)
    got = np.asarray(qmm.qmatmul_int(jnp.asarray(xq), jnp.asarray(wq)))
    want = xq.astype(np.int64) @ wq.astype(np.int64)
    assert (got == want).all()


@pytest.mark.parametrize("levels", [7, 127])
def test_qmatmul_matches_ref(levels):
    rng = np.random.default_rng(1)
    x = rng.standard_normal((32, 64)).astype(np.float32)
    w = rng.standard_normal((64, 48)).astype(np.float32)
    wq, ws = _quant_weight(w, levels)
    got = np.asarray(qmm.qmatmul(jnp.asarray(x), jnp.asarray(wq), jnp.asarray(ws),
                                 levels=levels, clip=0.9))
    want = np.asarray(ref.qmatmul(jnp.asarray(x), jnp.asarray(wq), jnp.asarray(ws),
                                  levels=levels, clip=0.9))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_integer_pipeline_equals_fake_quant_matmul():
    """deq(int_gemm(q(x), q(w))) == fake_quant(x) @ fake_quant(w).

    This is the identity that lets the accuracy graphs run with fake-quantized
    f32 weights while the perf kernels run the true integer pipeline — the
    same accuracy/perf split the paper itself uses (PyTorch fake quant for
    Tables 1-13, CUTLASS kernels for Figures 4/7).
    """
    rng = np.random.default_rng(2)
    x = rng.standard_normal((16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 24)).astype(np.float32)
    levels, clip = 7, 0.9
    wq, ws = _quant_weight(w, levels)
    w_deq = wq.astype(np.float32) * ws[None, :]
    x_deq = np.asarray(ref.fake_quant_act(jnp.asarray(x), float(levels), clip))
    fake = x_deq @ w_deq
    integer = np.asarray(
        qmm.qmatmul(jnp.asarray(x), jnp.asarray(wq), jnp.asarray(ws),
                    levels=levels, clip=clip))
    np.testing.assert_allclose(integer, fake, rtol=1e-4, atol=1e-4)


def test_qmatmul_accumulator_is_int32_exact():
    """Worst-case magnitudes must not saturate: 7*7*K << 2^31."""
    k = 4096
    xq = np.full((2, k), 7, dtype=np.int8)
    wq = np.full((k, 3), 7, dtype=np.int8)
    got = np.asarray(qmm.qmatmul_int(jnp.asarray(xq), jnp.asarray(wq)))
    assert (got == 7 * 7 * k).all()


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 40),
    k=st.integers(1, 100),
    n=st.integers(1, 40),
    levels=st.sampled_from([7, 31, 127]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_property(t, k, n, levels, seed):
    """Hypothesis sweep over shapes/levels: kernel == oracle exactly."""
    rng = np.random.default_rng(seed)
    xq = rng.integers(-levels, levels + 1, size=(t, k)).astype(np.int8)
    wq = rng.integers(-levels, levels + 1, size=(k, n)).astype(np.int8)
    got = np.asarray(qmm.qmatmul_int(jnp.asarray(xq), jnp.asarray(wq)))
    want = xq.astype(np.int64) @ wq.astype(np.int64)
    assert (got == want).all()
