"""Computational invariance (the heart of QuaRot, paper Sec. 3.4/4).

The rotated model run through the *rotated graph* (online Hadamards on) must
produce the same logits as the original model through the baseline graph —
in full precision, to f32 round-off.  Plus: the rotation actually kills the
outliers our synthetic checkpoints are constructed to have (Fig. 1).
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as M, quarot
from compile.configs import ModelConfig
from compile.hadamard_utils import random_orthogonal

TINY = ModelConfig(
    name="inv-mha", vocab=64, d_model=64, n_layers=2, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, max_seq=16, cache_seq=32, decode_batch=2)
GQA = dataclasses.replace(TINY, name="inv-gqa", n_kv_heads=2)
KRON = dataclasses.replace(TINY, name="inv-kron", d_ff=192)  # H_12 path
BASE = dataclasses.replace(M.BASELINE, use_kernels=False)
ROT = dataclasses.replace(M.QUAROT, quant_acts=False, use_kernels=False)


def _roundtrip(cfg, q_matrix=None, trained_gamma=True, seed=0):
    params = M.init_params(cfg, seed)
    if trained_gamma:  # exercise the norm-fusion path with non-trivial scales
        rng = np.random.default_rng(seed + 9)
        params = dict(params)
        params["attn_norm"] = jnp.asarray(
            1.0 + 0.3 * rng.standard_normal((cfg.n_layers, cfg.d_model)), jnp.float32)
        params["ffn_norm"] = jnp.asarray(
            1.0 + 0.3 * rng.standard_normal((cfg.n_layers, cfg.d_model)), jnp.float32)
        params["final_norm"] = jnp.asarray(
            1.0 + 0.3 * rng.standard_normal((cfg.d_model,)), jnp.float32)
    rot = {k: jnp.asarray(v) for k, v in
           quarot.rotate_params(cfg, {k: np.asarray(v) for k, v in params.items()},
                                seed=11, q_matrix=q_matrix).items()}
    toks = jnp.asarray(
        np.random.default_rng(seed).integers(0, cfg.vocab, (2, cfg.max_seq)),
        jnp.int32)
    l_base, _, _ = M.prefill(cfg, BASE, params, toks, 0.0, 1.0)
    l_rot, ks, vs = M.prefill(cfg, ROT, rot, toks, 0.0, 1.0)
    return np.asarray(l_base), np.asarray(l_rot), (params, rot, toks, ks, vs)


@pytest.mark.parametrize("cfg", [TINY, GQA, KRON], ids=["mha", "gqa", "kron12"])
def test_invariance_hadamard(cfg):
    l_base, l_rot, _ = _roundtrip(cfg)
    scale = np.abs(l_base).max()
    np.testing.assert_allclose(l_rot, l_base, atol=2e-3 * scale)


def test_invariance_random_orthogonal():
    """Table 8's ablation: any orthogonal Q preserves the model."""
    q = random_orthogonal(TINY.d_model, seed=5)
    l_base, l_rot, _ = _roundtrip(TINY, q_matrix=q)
    scale = np.abs(l_base).max()
    np.testing.assert_allclose(l_rot, l_base, atol=2e-3 * scale)


def test_invariance_with_kernels():
    """Same property through the Pallas-kernel graph (what actually ships)."""
    cfg = TINY
    params = M.init_params(cfg, 1)
    rot = {k: jnp.asarray(v) for k, v in
           quarot.rotate_params(cfg, {k: np.asarray(v) for k, v in params.items()},
                                seed=2).items()}
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (1, cfg.max_seq)), jnp.int32)
    l_base, _, _ = M.prefill(cfg, M.BASELINE, params, toks, 0.0, 1.0)
    rotk = dataclasses.replace(M.QUAROT, quant_acts=False)
    l_rot, _, _ = M.prefill(cfg, rotk, rot, toks, 0.0, 1.0)
    scale = np.abs(np.asarray(l_base)).max()
    np.testing.assert_allclose(np.asarray(l_rot), np.asarray(l_base),
                               atol=2e-3 * scale)


def test_decode_invariance():
    """Invariance holds through the decode path (quantized cache, 8-bit)."""
    from compile.kernels import ref
    cfg = GQA
    params = M.init_params(cfg, 2)
    rot = {k: jnp.asarray(v) for k, v in
           quarot.rotate_params(cfg, {k: np.asarray(v) for k, v in params.items()},
                                seed=3).items()}
    prompt = jnp.asarray(
        np.random.default_rng(2).integers(0, cfg.vocab, (1, 6)), jnp.int32)
    g_base = np.asarray(M.greedy_generate(cfg, BASE, params, prompt, 8))
    g_rot = np.asarray(M.greedy_generate(cfg, ROT, rot, prompt, 8))
    # argmax tokens are a robust invariance check through 8-bit caches
    assert (g_base == g_rot).mean() >= 0.75, (g_base, g_rot)


def test_rotation_removes_outliers():
    """Fig. 1: incoherence/outlier ratio of FFN inputs collapses after QuaRot."""
    cfg = dataclasses.replace(TINY, outlier_channels=4, outlier_scale=12.0)
    l_base, l_rot, (params, rot, toks, _, _) = _roundtrip(cfg, trained_gamma=False)

    # capture attention-input activations via the collect graph: layer 0 is
    # where the injected hot channels live at random init (in *trained*
    # checkpoints the residual stream carries them through every layer)
    outs_base = M.collect(cfg, BASE, params, toks)
    outs_rot = M.collect(cfg, ROT, rot, toks)
    amax_base = np.asarray(outs_base[1])   # amax_attn, (L, d)
    amax_rot = np.asarray(outs_rot[1])
    ratio_base = amax_base.max(1) / np.median(amax_base, 1)
    ratio_rot = amax_rot.max(1) / np.median(amax_rot, 1)
    assert ratio_base[0] > 4.0, ratio_base          # outliers exist pre-rotation
    assert ratio_rot[0] < ratio_base[0] / 3         # ... and QuaRot kills them
    assert (ratio_rot < 2.5).all(), ratio_rot       # uniform everywhere after


def test_fused_norms_preserve_model():
    cfg = TINY
    params = M.init_params(cfg, 4)
    rng = np.random.default_rng(5)
    params["attn_norm"] = jnp.asarray(
        1 + 0.5 * rng.standard_normal((cfg.n_layers, cfg.d_model)), jnp.float32)
    fused = {k: jnp.asarray(v, jnp.float32) for k, v in
             quarot.fuse_norms({k: np.asarray(v) for k, v in params.items()}).items()}
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    l1, _, _ = M.prefill(cfg, BASE, params, toks, 0.0, 1.0)
    l2, _, _ = M.prefill(cfg, BASE, fused, toks, 0.0, 1.0)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(l1),
                               atol=2e-3 * np.abs(np.asarray(l1)).max())
