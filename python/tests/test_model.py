"""Model forward: shapes, causality, decode/prefill consistency, GQA."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile.configs import ModelConfig
from compile.kernels import ref

TINY = ModelConfig(
    name="test-mha", vocab=64, d_model=64, n_layers=2, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, max_seq=16, cache_seq=32, decode_batch=2)
GQA = dataclasses.replace(TINY, name="test-gqa", n_kv_heads=2)
NOKERN = dataclasses.replace(M.BASELINE, use_kernels=False)
QUAROT_NOKERN = dataclasses.replace(M.QUAROT, use_kernels=False)


def _tokens(cfg, b=1, s=None, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, size=(b, s or cfg.max_seq)),
                       jnp.int32)


@pytest.mark.parametrize("cfg", [TINY, GQA], ids=["mha", "gqa"])
def test_prefill_shapes(cfg):
    params = M.init_params(cfg)
    toks = _tokens(cfg, b=2)
    logits, ks, vs = M.prefill(cfg, NOKERN, params, toks, 0.0, 1.0)
    s = cfg.max_seq
    assert logits.shape == (2, s, cfg.vocab)
    assert ks.shape == (cfg.n_layers, 2, s, cfg.n_kv_heads, cfg.d_head)
    assert vs.shape == ks.shape
    assert np.isfinite(np.asarray(logits)).all()


def test_causality():
    """Changing a future token must not change past logits."""
    cfg = TINY
    params = M.init_params(cfg)
    t1 = _tokens(cfg)
    t2 = np.asarray(t1).copy()
    t2[0, -1] = (t2[0, -1] + 1) % cfg.vocab
    l1, _, _ = M.prefill(cfg, NOKERN, params, t1, 0.0, 1.0)
    l2, _, _ = M.prefill(cfg, NOKERN, params, jnp.asarray(t2), 0.0, 1.0)
    np.testing.assert_allclose(np.asarray(l1)[0, :-1], np.asarray(l2)[0, :-1],
                               atol=1e-5)
    assert np.abs(np.asarray(l1)[0, -1] - np.asarray(l2)[0, -1]).max() > 1e-4


@pytest.mark.parametrize("cfg,mode", [
    (TINY, NOKERN), (GQA, NOKERN), (TINY, QUAROT_NOKERN), (GQA, QUAROT_NOKERN),
], ids=["mha-base", "gqa-base", "mha-quarot", "gqa-quarot"])
def test_decode_matches_prefill(cfg, mode):
    """Prefill(n+1) last-token logits == decode step given prefill(n) cache.

    Cache quantized at 8 bits / clip 1.0 so the comparison tolerance is
    dominated by the (small) KV quantization error.
    """
    params = M.init_params(cfg)
    b, s0 = 2, 8
    toks = _tokens(cfg, b=b, s=s0 + 1, seed=3)
    full_logits, _, _ = M.prefill(cfg, mode, params, toks, 0.0, 1.0)

    # build the cache from the first s0 tokens
    _, ks, vs = M.prefill(cfg, mode, params, toks[:, :s0], 0.0, 1.0)
    L, Hk, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    S, ng = cfg.cache_seq, cfg.d_head // cfg.group
    kc = jnp.zeros((L, b, S, Hk, dh), jnp.int8)
    side = jnp.zeros((L, b, S, Hk, ng), jnp.float32)
    q, sc, z = ref.kv_quant(ks, 8, cfg.group, 1.0)
    kcs = (kc.at[:, :, :s0].set(q), side.at[:, :, :s0].set(sc),
           side.at[:, :, :s0].set(z))
    q, sc, z = ref.kv_quant(vs, 8, cfg.group, 1.0)
    vcs = (kc.at[:, :, :s0].set(q), side.at[:, :, :s0].set(sc),
           side.at[:, :, :s0].set(z))
    cur = jnp.full((b,), s0, jnp.int32)
    logits, k_new, v_new = M.decode(cfg, mode, params, toks[:, s0], cur,
                                    kcs + vcs, 0.0, 1.0)
    assert k_new.shape == (L, b, Hk, dh)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, s0]),
                               rtol=0.05, atol=0.05)


def test_act_quant_changes_but_tracks_logits():
    cfg = TINY
    params = M.init_params(cfg)
    toks = _tokens(cfg)
    mode = dataclasses.replace(M.QUAROT, use_kernels=False)
    l16, _, _ = M.prefill(cfg, mode, params, toks, 0.0, 1.0)
    l8, _, _ = M.prefill(cfg, mode, params, toks, 127.0, 0.9)
    l4, _, _ = M.prefill(cfg, mode, params, toks, 7.0, 0.9)
    d8 = np.abs(np.asarray(l8) - np.asarray(l16)).mean()
    d4 = np.abs(np.asarray(l4) - np.asarray(l16)).mean()
    assert 0 < d8 < d4, (d8, d4)  # INT8 must hurt less than INT4


def test_outlier_mask_site_protection():
    """QUIK-style masks: protecting all channels == no quantization."""
    cfg = TINY
    params = M.init_params(cfg)
    toks = _tokens(cfg)
    mode = dataclasses.replace(M.BASELINE_QUANT, use_kernels=False)
    L = cfg.n_layers
    ones = {
        "mask_attn": jnp.ones((L, cfg.d_model)),
        "mask_out": jnp.ones((L, cfg.d_attn)),
        "mask_ffn": jnp.ones((L, cfg.d_model)),
        "mask_down": jnp.ones((L, cfg.d_ff)),
    }
    zeros = {k: jnp.zeros_like(v) for k, v in ones.items()}
    lfp, _, _ = M.prefill(cfg, mode, params, toks, 0.0, 1.0, masks=zeros)
    lq, _, _ = M.prefill(cfg, mode, params, toks, 7.0, 0.9, masks=zeros)
    lprot, _, _ = M.prefill(cfg, mode, params, toks, 7.0, 0.9, masks=ones)
    np.testing.assert_allclose(np.asarray(lprot), np.asarray(lfp), atol=1e-5)
    assert np.abs(np.asarray(lq) - np.asarray(lfp)).max() > 1e-3


def test_kernel_and_ref_modes_agree():
    """Pallas-kernel graph == pure-jnp graph (QuaRot mode, quantized)."""
    cfg = TINY
    params = M.init_params(cfg)
    toks = _tokens(cfg)
    lk, ksk, vsk = M.prefill(cfg, M.QUAROT, params, toks, 7.0, 0.9)
    lr, ksr, vsr = M.prefill(cfg, QUAROT_NOKERN, params, toks, 7.0, 0.9)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(ksk), np.asarray(ksr), atol=2e-4)


def test_collect_stats_shapes_and_psd():
    cfg = TINY
    params = M.init_params(cfg)
    toks = _tokens(cfg, b=2)
    outs = M.collect(cfg, QUAROT_NOKERN, params, toks)
    h1, a1, h2, a2, h3, a3, h4, a4, logit_amax = outs
    assert logit_amax.shape == (cfg.vocab,)
    L = cfg.n_layers
    assert h1.shape == (L, cfg.d_model, cfg.d_model)
    assert h4.shape == (L, cfg.d_ff, cfg.d_ff)
    assert a2.shape == (L, cfg.d_attn)
    for h in (h1, h2, h3, h4):  # Hessian contributions are PSD Gram matrices
        eig = np.linalg.eigvalsh(np.asarray(h[0], np.float64))
        assert eig.min() > -1e-6 * eig.max()  # PSD up to f32 round-off


def test_greedy_generate_deterministic():
    cfg = TINY
    params = M.init_params(cfg)
    prompt = _tokens(cfg, b=1, s=4)
    g1 = np.asarray(M.greedy_generate(cfg, NOKERN, params, prompt, 5))
    g2 = np.asarray(M.greedy_generate(cfg, NOKERN, params, prompt, 5))
    assert g1.shape == (1, 5)
    assert (g1 == g2).all()
    assert (g1 >= 0).all() and (g1 < cfg.vocab).all()
