"""Artifact container round-trips + synthetic-language sanity."""

import numpy as np

from compile import data, io


def test_weights_roundtrip(tmp_path):
    p = str(tmp_path / "w.bin")
    tensors = {
        "a.f32": np.random.default_rng(0).standard_normal((3, 4)).astype(np.float32),
        "b.i8": np.random.default_rng(1).integers(-7, 8, (2, 5, 6)).astype(np.int8),
        "c.scalar": np.asarray([3.0], np.float32),
        "d.i32": np.arange(7, dtype=np.int32),
    }
    io.write_weights(p, tensors)
    back = io.read_weights(p)
    assert set(back) == set(tensors)
    for k in tensors:
        assert back[k].dtype == tensors[k].dtype
        np.testing.assert_array_equal(back[k], tensors[k])


def test_corpus_roundtrip(tmp_path):
    p = str(tmp_path / "c.bin")
    splits = {
        "train": np.arange(100, dtype=np.uint16),
        "eval": np.asarray([5, 1, 2], np.uint16),
    }
    io.write_corpus(p, 512, splits)
    vocab, back = io.read_corpus(p)
    assert vocab == 512
    for k in splits:
        np.testing.assert_array_equal(back[k], splits[k])


def test_probes_roundtrip(tmp_path):
    p = str(tmp_path / "p.bin")
    tasks = data.build_probes(64, seed=0, n_items=5)
    io.write_probes(p, tasks)
    back = io.read_probes(p)
    assert [t["name"] for t in back] == [t["name"] for t in tasks]
    for t0, t1 in zip(tasks, back):
        assert len(t0["items"]) == len(t1["items"])
        i0, i1 = t0["items"][0], t1["items"][0]
        np.testing.assert_array_equal(i0["ctx"], i1["ctx"])
        if i0["choices"]:
            assert i0["gold"] == i1["gold"]
            for c0, c1 in zip(i0["choices"], i1["choices"]):
                np.testing.assert_array_equal(c0, c1)
        else:
            assert i0["gold_token"] == i1["gold_token"]


def test_language_statistics():
    lang = data.BigramLanguage(128, seed=0)
    rng = np.random.default_rng(0)
    toks = lang.sample_fast(20_000, rng)
    assert toks.min() >= 0 and toks.max() < 128
    # the chain must be markedly lower-entropy than uniform
    counts = np.bincount(toks, minlength=128).astype(np.float64)
    p = counts / counts.sum()
    ent = -(p[p > 0] * np.log(p[p > 0])).sum()
    assert ent < np.log(128)  # marginal is mildly skewed (mixture flattens it)
    # bigram structure: successor entropy given a frequent token is low
    top = int(np.argmax(counts))
    succ = toks[1:][toks[:-1] == top]
    sp = np.bincount(succ, minlength=128).astype(np.float64)
    sp /= sp.sum()
    s_ent = -(sp[sp > 0] * np.log(sp[sp > 0])).sum()
    assert s_ent < ent * 0.9  # real bigram structure: conditionals are sharp


def test_probe_tasks_are_solvable_by_oracle():
    """The data-generating process itself must rank gold > distractor."""
    lang = data.BigramLanguage(64, seed=1)
    tasks = data.build_probes(64, seed=1, n_items=40)

    def logprob(ctx, cont):
        lp, prev = 0.0, int(ctx[-1])
        for t in cont:
            lp += np.log(lang.trans[prev, int(t)])
            prev = int(t)
        return lp

    for t in tasks:
        if not t["items"][0]["choices"]:
            continue
        correct = 0
        for it in t["items"]:
            scores = [logprob(it["ctx"], c) for c in it["choices"]]
            correct += int(np.argmax(scores) == it["gold"])
        acc = correct / len(t["items"])
        n = len(t["items"][0]["choices"])
        assert acc > 1.0 / n + 0.1, (t["name"], acc)  # oracle beats chance
