//! Paper Table 8 (Appendix A.5) — randomized Hadamard Q vs QR-of-Gaussian
//! random orthogonal Q for the fused rotation (online ops stay Hadamard).
//! Expected shape: Hadamard < random-orthogonal < unrotated RTN.

use anyhow::Result;

use quarot::bench_support::{record, Artifacts, CheckSink};
use quarot::coordinator::runner::{QuantSpec, Variant};
use quarot::eval;
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table8_random_orth");
    let windows = chk.windows();
    let mut t = Table::new("Table 8 — rotation matrix ablation (W4A4KV4 RTN)",
                           &["model", "rotation", "ppl"]);
    for model in ["tiny-mha", "tiny-gqa"] {
        let art = match Artifacts::load(model) {
            Ok(a) => a,
            Err(_) => continue,
        };
        let eval_toks = art.corpus.split("eval")?;
        {
            let fp = art.runner_prefill_only(QuantSpec::fp16_baseline(), None)?;
            let p = eval::perplexity(&fp, eval_toks, windows)?;
            chk.cell("Baseline FP16", p)?;
            t.row(vec![model.into(), "Baseline FP16".into(),
                       format!("{p:.4}")]);
        }
        for (label, variant) in [("QuaRot (Hadamard)", Variant::Quarot),
                                 ("QuaRot (Random orth.)", Variant::QuarotRandom)] {
            let spec = QuantSpec { variant, ..QuantSpec::quarot(4) };
            let runner = art.runner_prefill_only(spec, None)?;
            let p = eval::perplexity(&runner, eval_toks, windows)?;
            chk.cell(label, p)?;
            println!("  [{model}] {label}: {p:.4}");
            t.row(vec![model.into(), label.into(), format!("{p:.4}")]);
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table8_random_orth", &t.render())
}
