//! Rotation-scheme × precision-tier matrix: every selectable
//! [`RotationKind`] crossed with the KV4 / KV8 quality tiers.
//!
//! Full mode tabulates eval perplexity and decode throughput for each
//! (scheme, tier) cell — the serving-facing restatement of the paper's
//! Table 8 (rotation ablation) and Table 6 (KV-bit grid): rotations
//! decide how well activations quantize, tiers decide how wide the KV
//! cache is per request, and the two compose.
//!
//! `--check` is the CI acceptance smoke:
//!   * every scheme × tier cell builds a runner end-to-end and yields a
//!     finite perplexity (a broken rotation shows up as NaN/inf);
//!   * a mixed KV4/KV8 workload on one engine retires every request and
//!     the per-tier counters partition the totals exactly
//!     (`kv4_completed + kv8_completed == completed`, same for
//!     `decode_tokens`) with both tiers represented.
//!
//! Like the other benches it self-skips with exit 0 when AOT artifacts
//! are absent, so CI stays green on runners without `make artifacts`.

use anyhow::{anyhow, bail, Result};

use quarot::api::{GenerationParams, LocalSession, QualityTier,
                  SessionConfig};
use quarot::bench_support::{eval_windows, record, Artifacts};
use quarot::coordinator::batcher::GenerationEngine;
use quarot::coordinator::runner::{QuantSpec, Runner};
use quarot::eval;
use quarot::rotation::RotationKind;
use quarot::util::bench::Table;

const MODEL: &str = "tiny-mha";
const SEED: u64 = 33;
const PAGES: usize = 2048;
const N_REQS: usize = 8;
const PROMPT_LEN: usize = 24;
const MAX_NEW: usize = 8;

/// Runner for `kind` with the KV cache at `kv_bits`, collecting
/// calibration stats when the scheme needs them (scaled-hadamard folds
/// per-channel scales into the weights, which requires activation amax).
fn runner_for(art: &Artifacts, kind: RotationKind, kv_bits: u32)
    -> Result<Runner>
{
    let mut spec = QuantSpec::quarot(4);
    spec.kv_bits = kv_bits;
    spec.kv_bits_v = kv_bits;
    kind.apply_to_spec(&mut spec)?;
    let stats = if spec.smooth {
        Some(art.calib(spec.variant.is_rotated(), 4)?)
    } else {
        None
    };
    art.runner(spec, stats.as_ref())
}

fn prompts(art: &Artifacts) -> Result<Vec<Vec<u16>>> {
    let eval_toks = art.corpus.split("eval")?;
    if eval_toks.len() < PROMPT_LEN * 8 {
        bail!("eval split too short ({} tokens)", eval_toks.len());
    }
    Ok((0..N_REQS)
        .map(|i| {
            let off = (i * 37) % (eval_toks.len() - PROMPT_LEN);
            eval_toks[off..off + PROMPT_LEN].to_vec()
        })
        .collect())
}

/// Decode throughput for one cell: drive a small single-tier workload
/// through an engine and read the aggregate tokens/sec.
fn decode_tps(art: &Artifacts, runner: Runner, tier: QualityTier)
    -> Result<f64>
{
    let engine = GenerationEngine::new(runner, PAGES, SEED);
    let session = LocalSession::new(engine, SessionConfig::default());
    let handles = prompts(art)?
        .into_iter()
        .map(|p| {
            session
                .submit(GenerationParams::new(p).max_new(MAX_NEW).tier(tier))
                .map_err(|e| anyhow!("{e}"))
        })
        .collect::<Result<Vec<_>>>()?;
    for h in &handles {
        h.wait()?;
    }
    Ok(session.stats().tokens_per_sec())
}

/// Acceptance: every cell finite, plus exact per-tier counter
/// partitions under a mixed KV4/KV8 workload on a single engine.
fn check(art: &Artifacts) -> Result<()> {
    let windows = eval_windows();
    let eval_toks = art.corpus.split("eval")?;
    for kind in RotationKind::ALL {
        for kv_bits in [4u32, 8] {
            let runner = runner_for(art, kind, kv_bits)?;
            let p = eval::perplexity(&runner, eval_toks, windows)?;
            if !p.is_finite() {
                bail!("{kind} kv{kv_bits}: non-finite perplexity {p}");
            }
            println!("[check] {kind} kv{kv_bits}: ppl {p:.4} (finite)");
        }
    }

    let runner = runner_for(art, RotationKind::default(), 4)?;
    let engine = GenerationEngine::new(runner, PAGES, SEED);
    let session = LocalSession::new(engine, SessionConfig::default());
    let tiers = [QualityTier::Kv4, QualityTier::Kv8];
    let handles = prompts(art)?
        .into_iter()
        .enumerate()
        .map(|(i, p)| {
            session
                .submit(GenerationParams::new(p)
                    .max_new(MAX_NEW)
                    .tier(tiers[i % 2]))
                .map_err(|e| anyhow!("{e}"))
        })
        .collect::<Result<Vec<_>>>()?;
    for h in &handles {
        h.wait()?;
    }
    let s = session.stats();
    if s.completed != N_REQS {
        bail!("mixed-tier workload: {} of {N_REQS} completed", s.completed);
    }
    if s.kv4_completed + s.kv8_completed != s.completed {
        bail!("tier completion counters do not partition completed: \
               {} + {} != {}",
              s.kv4_completed, s.kv8_completed, s.completed);
    }
    if s.kv4_completed == 0 || s.kv8_completed == 0 {
        bail!("mixed workload lost a tier: kv4={} kv8={}",
              s.kv4_completed, s.kv8_completed);
    }
    if s.kv4_decode_tokens + s.kv8_decode_tokens != s.decode_tokens {
        bail!("tier token counters do not partition decode_tokens: \
               {} + {} != {}",
              s.kv4_decode_tokens, s.kv8_decode_tokens, s.decode_tokens);
    }
    if s.kv4_decode_tokens == 0 || s.kv8_decode_tokens == 0 {
        bail!("mixed workload decoded no tokens in a tier: kv4={} kv8={}",
              s.kv4_decode_tokens, s.kv8_decode_tokens);
    }
    println!("[check] mixed tiers: {} done (kv4 {} / kv8 {}), \
              {} decode tokens (kv4 {} / kv8 {})",
             s.completed, s.kv4_completed, s.kv8_completed,
             s.decode_tokens, s.kv4_decode_tokens, s.kv8_decode_tokens);
    Ok(())
}

fn main() -> Result<()> {
    let check_mode = std::env::args().any(|a| a == "--check");
    let art = match Artifacts::load(MODEL) {
        Ok(a) => a,
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            return Ok(());
        }
    };

    if check_mode {
        check(&art)?;
        println!("[check] rotation/tier matrix acceptance OK");
        return Ok(());
    }

    let windows = eval_windows();
    let eval_toks = art.corpus.split("eval")?;
    let mut t = Table::new(
        "Rotation scheme × KV precision tier (W4A4, tiny-mha)",
        &["rotation", "tier", "ppl", "decode tok/s"]);
    for kind in RotationKind::ALL {
        for (tier, kv_bits) in [(QualityTier::Kv4, 4u32),
                                (QualityTier::Kv8, 8)] {
            let runner = runner_for(&art, kind, kv_bits)?;
            let p = eval::perplexity(&runner, eval_toks, windows)?;
            let tps = decode_tps(&art, runner, tier)?;
            println!("  [{kind}] {}: ppl {p:.4}, {tps:.1} tok/s",
                     tier.as_str());
            t.row(vec![kind.to_string(), tier.as_str().into(),
                       format!("{p:.4}"), format!("{tps:.1}")]);
        }
    }
    record("rotation_tiers", &t.render())
}
