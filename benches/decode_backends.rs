//! Per-backend batched decode + NLL benches — the serving decode path's
//! kernel-authority table, alongside table14 (GEMM) and table16 (prefill).
//!
//! Sweeps every `ComputeBackend` (scalar oracle → cache-tiled blocked →
//! pool-threaded → auto) over one batched decode tick: ragged GQA
//! sequences (including an empty cache) against f32, packed-int4 and int8
//! KV streams, plus the batched `nll_rows` reduction the eval harness
//! uses.  Every backend's outputs are verified bit-exact against the
//! scalar oracle before timing.
//!
//! `--check` runs verification only (one rep per op, no timing) and fails
//! the process on any divergence — the CI dispatch-regression gate.

use anyhow::{bail, Result};

use quarot::attention::{CacheF32, CacheQuant, DecodeF32Seq, DecodeQuantSeq};
use quarot::backend::{self, BackendKind};
use quarot::bench_support::record;
use quarot::util::bench::{bench_auto, Table};
use quarot::util::prng::Rng;

fn main() -> Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    // LLAMA-like GQA decode geometry (scaled down in --check mode)
    let (hk, rep, dh) = (8usize, 4usize, 64usize);
    let nh = hk * rep;
    let group = 64usize.min(dh);
    let lens: Vec<usize> = if check {
        vec![48, 0, 33, 16]
    } else {
        vec![768, 512, 256, 64]
    };
    let mut rng = Rng::new(5);
    let mut caches = Vec::new();
    let mut qs: Vec<Vec<f32>> = Vec::new();
    for &len in &lens {
        let mut kf = CacheF32::new(hk, dh, len);
        let mut vf = CacheF32::new(hk, dh, len);
        let mut kq4 = CacheQuant::new(hk, dh, group, 4);
        let mut vq4 = CacheQuant::new(hk, dh, group, 4);
        let mut kq8 = CacheQuant::new(hk, dh, group, 8);
        let mut vq8 = CacheQuant::new(hk, dh, group, 8);
        for _ in 0..len {
            let kt = rng.normal_vec(hk * dh);
            let vt = rng.normal_vec(hk * dh);
            kf.append(&kt);
            vf.append(&vt);
            kq4.append(&kt, 0.95);
            vq4.append(&vt, 0.95);
            kq8.append(&kt, 0.95);
            vq8.append(&vt, 0.95);
        }
        caches.push((kf, vf, kq4, vq4, kq8, vq8));
        qs.push(rng.normal_vec(nh * dh));
    }
    let seqs_f: Vec<DecodeF32Seq> = caches.iter().zip(&qs)
        .map(|((kf, vf, ..), q)| DecodeF32Seq { q, k: kf.view(), v: vf.view() })
        .collect();
    let seqs_q4: Vec<DecodeQuantSeq> = caches.iter().zip(&qs)
        .map(|((_, _, kq, vq, _, _), q)| DecodeQuantSeq {
            q, k: kq.view(), v: vq.view(),
        })
        .collect();
    let seqs_q8: Vec<DecodeQuantSeq> = caches.iter().zip(&qs)
        .map(|((.., kq, vq), q)| DecodeQuantSeq {
            q, k: kq.view(), v: vq.view(),
        })
        .collect();
    // eval-harness NLL workload (one perplexity window's worth of rows)
    let (vocab, rows) = if check { (512usize, 32usize) } else { (4096, 256) };
    let logits = rng.normal_vec(rows * vocab);
    let targets: Vec<u16> = (0..rows).map(|_| rng.below(vocab) as u16).collect();

    // scalar oracle reference outputs
    let n_out = lens.len() * nh * dh;
    let scalar = backend::make(BackendKind::Scalar);
    let mut ref_f = vec![0.0f32; n_out];
    let mut ref_q4 = vec![0.0f32; n_out];
    let mut ref_q8 = vec![0.0f32; n_out];
    let mut ref_nll = vec![0.0f64; rows];
    scalar.decode_f32_batch(&seqs_f, nh, &mut ref_f);
    scalar.decode_quant_batch(&seqs_q4, nh, &mut ref_q4);
    scalar.decode_quant_batch(&seqs_q8, nh, &mut ref_q8);
    scalar.nll_rows(&logits, vocab, &targets, &mut ref_nll);
    if ref_f.iter().any(|v| !v.is_finite()) {
        bail!("scalar oracle produced non-finite decode output");
    }

    let mut t = Table::new(
        "Decode ops per backend — batched ragged-GQA decode + NLL (ms/tick)",
        &["backend", "f32", "int4", "int8", "nll", "i4 vs scalar"]);
    let mut scalar_i4_ms = f64::NAN;
    for kind in BackendKind::all() {
        let be = backend::make(kind);
        // bit-exactness gate first — a dispatch regression fails here
        // before any timing noise can hide it
        let mut out = vec![f32::NAN; n_out];
        be.decode_f32_batch(&seqs_f, nh, &mut out);
        if out != ref_f {
            bail!("{}: batched f32 decode diverged from the scalar oracle",
                  be.name());
        }
        out.fill(f32::NAN);
        be.decode_quant_batch(&seqs_q4, nh, &mut out);
        if out != ref_q4 {
            bail!("{}: batched int4 decode diverged from the scalar oracle",
                  be.name());
        }
        out.fill(f32::NAN);
        be.decode_quant_batch(&seqs_q8, nh, &mut out);
        if out != ref_q8 {
            bail!("{}: batched int8 decode diverged from the scalar oracle",
                  be.name());
        }
        let mut nll = vec![f64::NAN; rows];
        be.nll_rows(&logits, vocab, &targets, &mut nll);
        if nll != ref_nll {
            bail!("{}: batched NLL diverged from the scalar oracle", be.name());
        }
        if check {
            println!("[check] {}: decode f32/int4/int8 + nll bit-exact vs \
                      scalar", be.name());
            continue;
        }
        let budget = 150.0;
        let s_f32 = bench_auto(budget, || be.decode_f32_batch(&seqs_f, nh, &mut out));
        let s_i4 = bench_auto(budget, || be.decode_quant_batch(&seqs_q4, nh, &mut out));
        let s_i8 = bench_auto(budget, || be.decode_quant_batch(&seqs_q8, nh, &mut out));
        let s_nll = bench_auto(budget, || be.nll_rows(&logits, vocab, &targets, &mut nll));
        if kind == BackendKind::Scalar {
            scalar_i4_ms = s_i4.median_ms();
        }
        let vs_scalar = scalar_i4_ms / s_i4.median_ms();
        println!("  [{}] f32 {:.3}ms i4 {:.3}ms i8 {:.3}ms nll {:.3}ms \
                  ({vs_scalar:.2}x vs scalar)",
                 be.name(), s_f32.median_ms(), s_i4.median_ms(),
                 s_i8.median_ms(), s_nll.median_ms());
        t.row(vec![
            be.name().into(),
            format!("{:.3}", s_f32.median_ms()),
            format!("{:.3}", s_i4.median_ms()),
            format!("{:.3}", s_i8.median_ms()),
            format!("{:.3}", s_nll.median_ms()),
            format!("{vs_scalar:.2}x"),
        ]);
    }
    if check {
        println!("[check] all backends dispatch batched decode + NLL and \
                  match the oracle");
        return Ok(());
    }
    record("decode_backends", &t.render())
}
