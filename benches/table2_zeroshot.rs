//! Paper Table 2 — zero-shot accuracy (six probe tasks) of FP16 vs 4-bit
//! QuaRot.  Expected shape: QuaRot within a few points of FP16, with the
//! gap shrinking for the larger/GQA configs.

use anyhow::Result;

use quarot::bench_support::{available_models, probe_items, record, Artifacts,
                            CheckSink};
use quarot::coordinator::runner::{QuantSpec, WeightQuant};
use quarot::eval;
use quarot::quant::gptq::GptqCfg;
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table2_zeroshot");
    let items = if chk.active() { 4 } else { probe_items() };
    let mut header = vec!["model".to_string(), "method".to_string()];
    let mut t: Option<Table> = None;
    for model in available_models() {
        let art = Artifacts::load(&model)?;
        let calib_rot = art.calib(true, 4)?;
        for (label, spec) in [
            ("FP16", QuantSpec::fp16_baseline()),
            ("QuaRot", QuantSpec {
                weights: WeightQuant::Gptq(GptqCfg::new(4), calib_rot.clone()),
                ..QuantSpec::quarot(4)
            }),
        ] {
            let runner = art.runner_prefill_only(spec, None)?;
            let (scores, avg) = eval::score_all(&runner, &art.probes, items)?;
            if t.is_none() {
                header.extend(scores.iter().map(|s| s.name.clone()));
                header.push("Avg.".into());
                let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
                t = Some(Table::new("Table 2 — zero-shot probe accuracy", &hrefs));
            }
            let mut row = vec![model.clone(), label.to_string()];
            row.extend(scores.iter().map(|s| format!("{:.3}", s.accuracy)));
            row.push(format!("{avg:.3}"));
            chk.cell(label, avg)?;
            println!("  [{model}] {label}: avg {avg:.3}");
            t.as_mut().unwrap().row(row);
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table2_zeroshot", &t.unwrap().render())
}
