//! Paper Table 4 — group-size ablation for QuaRot-GPTQ weights
//! (per-column vs 256G/128G/64G).  Expected shape: smaller groups →
//! monotonically better ppl, diminishing returns.

use anyhow::Result;

use quarot::bench_support::{record, Artifacts, CheckSink};
use quarot::coordinator::runner::{QuantSpec, WeightQuant};
use quarot::eval;
use quarot::quant::gptq::GptqCfg;
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table4_groupsize");
    let windows = chk.windows();
    let model = "tiny-mha";
    let art = match Artifacts::load(model) {
        Ok(a) => a,
        Err(e) if chk.active() => {
            println!("[check] table4_groupsize skipped: {e}");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let eval_toks = art.corpus.split("eval")?;
    let calib_rot = art.calib(true, 4)?;

    let mut t = Table::new("Table 4 — group-wise weight quantization",
                           &["method", "ppl"]);
    let fp = art.runner_prefill_only(QuantSpec::fp16_baseline(), None)?;
    let p_base = eval::perplexity(&fp, eval_toks, windows)?;
    chk.cell("Baseline", p_base)?;
    t.row(vec!["Baseline".into(), format!("{p_base:.4}")]);
    drop(fp);
    // group sizes must divide every weight's input dim; tiny-mha: 256/1024
    for (label, group) in [("QuaRot (per-column)", 0usize),
                           ("QuaRot-256G", 256), ("QuaRot-128G", 128),
                           ("QuaRot-64G", 64)] {
        let spec = QuantSpec {
            weights: WeightQuant::Gptq(GptqCfg::grouped(4, group), calib_rot.clone()),
            ..QuantSpec::quarot(4)
        };
        let runner = art.runner_prefill_only(spec, None)?;
        let p = eval::perplexity(&runner, eval_toks, windows)?;
        chk.cell(label, p)?;
        println!("  {label:24} {p:.4}");
        t.row(vec![label.into(), format!("{p:.4}")]);
    }
    if chk.done() {
        return Ok(());
    }
    record("table4_groupsize", &t.render())
}
