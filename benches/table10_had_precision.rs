//! Paper Table 10 (Appendix A.7) — precision of the *online* Hadamard
//! transforms: f32 vs bf16 (the paper's FP32-vs-FP16 ablation, emulated on
//! the f32 CPU runtime by rounding Hadamard outputs to bf16 in-graph).
//! Expected shape: indistinguishable (the paper concludes "noise").

use anyhow::Result;

use quarot::bench_support::{record, Artifacts, CheckSink};
use quarot::coordinator::runner::{QuantSpec, Variant};
use quarot::eval;
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table10_had_precision");
    let windows = chk.windows();
    let mut t = Table::new("Table 10 — online-Hadamard precision (W4A4KV4 RTN)",
                           &["model", "had precision", "ppl"]);
    for model in ["tiny-mha", "small-mha"] {
        let art = match Artifacts::load(model) {
            Ok(a) => a,
            Err(_) => continue,
        };
        let eval_toks = art.corpus.split("eval")?;
        for (label, variant) in [("f32", Variant::Quarot),
                                 ("bf16", Variant::QuarotH16)] {
            let spec = QuantSpec { variant, ..QuantSpec::quarot(4) };
            let runner = art.runner_prefill_only(spec, None)?;
            let p = eval::perplexity(&runner, eval_toks, windows)?;
            chk.cell(label, p)?;
            println!("  [{model}] had {label}: {p:.4}");
            t.row(vec![model.into(), label.into(), format!("{p:.4}")]);
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table10_had_precision", &t.render())
}
