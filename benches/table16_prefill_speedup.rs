//! Paper Fig. 4 (left) / Table 16 — prefill speedup of the 4-bit block vs
//! FP16 across batch sizes.  Composed from measured native-GEMM times for
//! every linear layer of one transformer block (the same methodology as
//! the paper's single-block measurement), LLaMA-7B and 70B shapes, seq
//! scaled to keep low-core runtime sane.  Expected shape: speedup grows
//! with batch and with model size (paper: 1.97→2.16× on 7B, 3.16→3.33×
//! on 70B).
//!
//! The whole block is measured once per compute backend on the same
//! prepared activations/weights; the last column reports each backend's
//! int4 block time against the `scalar` oracle — the prefill-side view
//! of the backend-subsystem speedup.

use anyhow::Result;

use quarot::backend::{self, BackendKind};
use quarot::bench_support::{record, CheckSink};
use quarot::gemm;
use quarot::util::bench::{bench, Table};
use quarot::util::prng::Rng;

struct BlockShape {
    name: &'static str,
    d: usize,
    d_kv: usize,
    dff: usize,
}

fn main() -> Result<()> {
    // paper shapes scaled 1/8 in width (runtime ∝ d², still bandwidth-true)
    let blocks = [
        BlockShape { name: "LLAMA2-7B/8", d: 512, d_kv: 512, dff: 1376 },
        BlockShape { name: "LLAMA2-70B/8", d: 1024, d_kv: 128, dff: 3584 },
    ];
    let mut chk = CheckSink::new("table16_prefill_speedup");
    // `--check`: tiny token count, single batch — still composes the
    // full 7-layer block on every backend
    let seq = if chk.active() { 8usize } else { 64 };
    let batches: &[usize] = if chk.active() { &[1] } else { &[1, 4, 16] };
    let mut t = Table::new(
        "Fig 4L / Table 16 — prefill block speedup (int4 vs f32, composed)",
        &["backend", "block", "batch", "f32 ms", "int4 ms", "speedup",
          "i4 vs scalar"]);
    let mut rng = Rng::new(2);
    for b in &blocks {
        // per-block linear layers: wq(d,d) wk/wv(d,dkv) wo(d,d)
        // wup/wgate(d,dff) wdown(dff,d)
        let layers: Vec<(usize, usize)> = vec![
            (b.d, b.d), (b.d, b.d_kv), (b.d, b.d_kv), (b.d, b.d),
            (b.d, b.dff), (b.d, b.dff), (b.dff, b.d),
        ];
        let prepared: Vec<(gemm::WeightsF32, gemm::WeightsI4)> = layers.iter()
            .map(|&(k, n)| {
                let w = rng.normal_vec(k * n);
                (gemm::WeightsF32::from_row_major(&w, k, n),
                 gemm::WeightsI4::quantize(&w, k, n))
            })
            .collect();
        for &batch in batches {
            let tokens = seq * batch;
            // one activation set per (block, batch) — shared by backends
            let xs: Vec<Vec<f32>> = layers.iter()
                .map(|&(k, _)| rng.normal_vec(tokens * k))
                .collect();
            let mut scalar_i4_ms = f64::NAN;
            for kind in [BackendKind::Scalar, BackendKind::Blocked,
                         BackendKind::Threaded] {
                let be = backend::make(kind);
                let mut f32_ms = 0.0f64;
                let mut i4_ms = 0.0f64;
                for (i, &(_, n)) in layers.iter().enumerate() {
                    let x = &xs[i];
                    let mut y = vec![0.0f32; tokens * n];
                    let (wf, w4) = &prepared[i];
                    f32_ms += bench(1, 3, || be.gemm_f32(x, tokens, wf, &mut y))
                        .median_ms();
                    i4_ms += bench(1, 3, || {
                        be.gemm_i4(x, tokens, w4, 0.9, &mut y)
                    }).median_ms();
                }
                if kind == BackendKind::Scalar {
                    scalar_i4_ms = i4_ms;
                }
                chk.cell("f32 block", f32_ms)?;
                chk.cell("int4 block", i4_ms)?;
                let sp = f32_ms / i4_ms;
                let vs_scalar = scalar_i4_ms / i4_ms;
                println!("  [{}] {} b={batch}: f32 {f32_ms:.1}ms i4 {i4_ms:.1}ms \
                          → {sp:.2}x ({vs_scalar:.2}x vs scalar)",
                         be.name(), b.name);
                t.row(vec![be.name().into(), b.name.into(), format!("{batch}"),
                           format!("{f32_ms:.1}"), format!("{i4_ms:.1}"),
                           format!("{sp:.2}x"), format!("{vs_scalar:.2}x")]);
            }
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table16_prefill_speedup", &t.render())
}
