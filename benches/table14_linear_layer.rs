//! Paper Fig. 7 / Table 14 — the 4-bit linear layer vs the high-precision
//! baseline, with and without the online Hadamard transform, across the
//! LLaMA FFN layer shapes.  Staged on the native CPU GEMM substrate
//! (DESIGN.md §1): the reproduction target is the *ratio* (paper: 3.2-4.3×
//! on a 3090) and the ≤7 % Hadamard overhead, not absolute ms.
//!
//! Shapes are scaled-down (seq 256; the paper's K×N kept for the two
//! in-model sizes, plus the real LLaMA shapes at reduced seq to keep the
//! 1-core runtime sane).

use anyhow::Result;

use quarot::gemm;
use quarot::hadamard;
use quarot::bench_support::record;
use quarot::util::bench::{bench_auto, Table};
use quarot::util::prng::Rng;

fn main() -> Result<()> {
    let t_tokens = 64usize;
    let shapes: &[(usize, usize)] = &[
        (1024, 256),   // tiny-mha W_down
        (256, 1024),   // tiny-mha W_up
        (4096, 4096),  // LLAMA2-7B attn (paper row 1)
        (2560, 1024), // LLAMA2-7B W_down-like, 2^7·20 exercises the H20 path
    ];
    let mut t = Table::new(
        "Fig 7 / Table 14 — linear layer: f32 vs int8 vs packed-int4 (ms)",
        &["K x N", "f32", "int8", "int4", "int4+had", "speedup4",
          "had ovh %"]);
    let mut rng = Rng::new(0);
    for &(k, n) in shapes {
        let x: Vec<f32> = rng.normal_vec(t_tokens * k);
        let w: Vec<f32> = rng.normal_vec(k * n);
        let wf = gemm::WeightsF32::from_row_major(&w, k, n);
        let w8 = gemm::WeightsI8::quantize(&w, k, n, 8);
        let w4 = gemm::WeightsI4::quantize(&w, k, n);
        let mut y = vec![0.0f32; t_tokens * n];
        let mut scratch: Vec<i8> = Vec::new();
        let budget = 300.0;

        let s_f32 = bench_auto(budget, || gemm::gemm_f32(&x, t_tokens, &wf, &mut y));
        let s_i8 = bench_auto(budget, || {
            gemm::gemm_i8(&x, t_tokens, &w8, 8, 0.9, &mut y, &mut scratch)
        });
        let s_i4 = bench_auto(budget, || {
            gemm::gemm_i4(&x, t_tokens, &w4, 0.9, &mut y, &mut scratch)
        });
        // int4 + online Hadamard on the activation (the W_down path)
        let mut xh = x.clone();
        let s_i4h = bench_auto(budget, || {
            xh.copy_from_slice(&x);
            for row in xh.chunks_exact_mut(k) {
                hadamard::wht(row);
            }
            gemm::gemm_i4(&xh, t_tokens, &w4, 0.9, &mut y, &mut scratch)
        });
        let sp = s_f32.median_ms() / s_i4.median_ms();
        let ovh = (s_i4h.median_ms() / s_i4.median_ms() - 1.0) * 100.0;
        println!("  {k}x{n}: f32 {:.2}ms i4 {:.2}ms → {sp:.2}x (had +{ovh:.1}%)",
                 s_f32.median_ms(), s_i4.median_ms());
        t.row(vec![
            format!("{k}x{n}"),
            format!("{:.2}", s_f32.median_ms()),
            format!("{:.2}", s_i8.median_ms()),
            format!("{:.2}", s_i4.median_ms()),
            format!("{:.2}", s_i4h.median_ms()),
            format!("{sp:.2}x"),
            format!("{ovh:.1}"),
        ]);
    }
    record("table14_linear_layer", &t.render())
}
