//! Paper Fig. 7 / Table 14 — the 4-bit linear layer vs the high-precision
//! baseline, with and without the online Hadamard transform, across the
//! LLaMA FFN layer shapes.  Staged on the native CPU kernels (DESIGN.md
//! §1): the reproduction target is the *ratio* (paper: 3.2-4.3× on a
//! 3090) and the ≤7 % Hadamard overhead, not absolute ms.
//!
//! Runs every shape through each compute backend (scalar oracle →
//! cache-blocked → pool-threaded) on the *same* prepared matrices, and
//! reports per backend the int4-vs-f32 speedup plus the backend's int4
//! speedup over the `scalar` int4 baseline — the acceptance number for
//! the backend subsystem (threaded ≥ 2× scalar on these shapes,
//! bit-exact on the int paths).
//!
//! Shapes are scaled-down (seq 256; the paper's K×N kept for the two
//! in-model sizes, plus the real LLaMA shapes at reduced seq to keep the
//! low-core runtime sane).

use anyhow::Result;

use quarot::backend::{self, BackendKind};
use quarot::bench_support::{record, CheckSink};
use quarot::gemm;
use quarot::util::bench::{bench_auto, Table};
use quarot::util::prng::Rng;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table14_linear_layer");
    let t_tokens = if chk.active() { 8usize } else { 64 };
    let all_shapes: &[(usize, usize)] = &[
        (1024, 256),   // tiny-mha W_down
        (256, 1024),   // tiny-mha W_up
        (4096, 4096),  // LLAMA2-7B attn (paper row 1)
        (2560, 1024), // LLAMA2-7B W_down-like, 2^7·20 exercises the H20 path
    ];
    // `--check`: one rep per kernel on the small shapes only — the
    // smoke drives every backend × gemm path, not the timing sweep
    let shapes = if chk.active() { &all_shapes[..2] } else { all_shapes };
    let mut t = Table::new(
        "Fig 7 / Table 14 — linear layer per backend: f32 vs int8 vs packed-int4 (ms)",
        &["backend", "K x N", "f32", "int8", "int4", "int4+had", "i4 vs f32",
          "had ovh %", "i4 vs scalar"]);
    let mut rng = Rng::new(0);
    for &(k, n) in shapes {
        // one prepared problem per shape — every backend times the same data
        let x: Vec<f32> = rng.normal_vec(t_tokens * k);
        let w: Vec<f32> = rng.normal_vec(k * n);
        let wf = gemm::WeightsF32::from_row_major(&w, k, n);
        let w8 = gemm::WeightsI8::quantize(&w, k, n, 8);
        let w4 = gemm::WeightsI4::quantize(&w, k, n);
        let mut y = vec![0.0f32; t_tokens * n];
        let mut xh = x.clone();
        let budget = if chk.active() { 1.0 } else { 200.0 };
        let mut scalar_i4_ms = f64::NAN;
        for kind in [BackendKind::Scalar, BackendKind::Blocked,
                     BackendKind::Threaded] {
            let be = backend::make(kind);

            let s_f32 = bench_auto(budget, || be.gemm_f32(&x, t_tokens, &wf, &mut y));
            let s_i8 = bench_auto(budget, || {
                be.gemm_i8(&x, t_tokens, &w8, 8, 0.9, &mut y)
            });
            let s_i4 = bench_auto(budget, || {
                be.gemm_i4(&x, t_tokens, &w4, 0.9, &mut y)
            });
            // int4 + online Hadamard on the activation (the W_down path)
            let s_i4h = bench_auto(budget, || {
                xh.copy_from_slice(&x);
                be.had_rows(&mut xh, k);
                be.gemm_i4(&xh, t_tokens, &w4, 0.9, &mut y)
            });
            if kind == BackendKind::Scalar {
                scalar_i4_ms = s_i4.median_ms();
            }
            for (label, s) in [("f32", &s_f32), ("int8", &s_i8),
                               ("int4", &s_i4), ("int4+had", &s_i4h)] {
                chk.cell(label, s.median_ms())?;
            }
            let sp = s_f32.median_ms() / s_i4.median_ms();
            let ovh = (s_i4h.median_ms() / s_i4.median_ms() - 1.0) * 100.0;
            let vs_scalar = scalar_i4_ms / s_i4.median_ms();
            println!("  [{}] {k}x{n}: f32 {:.2}ms i4 {:.2}ms → {sp:.2}x \
                      (had +{ovh:.1}%, {vs_scalar:.2}x vs scalar)",
                     be.name(), s_f32.median_ms(), s_i4.median_ms());
            t.row(vec![
                be.name().into(),
                format!("{k}x{n}"),
                format!("{:.2}", s_f32.median_ms()),
                format!("{:.2}", s_i8.median_ms()),
                format!("{:.2}", s_i4.median_ms()),
                format!("{:.2}", s_i4h.median_ms()),
                format!("{sp:.2}x"),
                format!("{ovh:.1}"),
                format!("{vs_scalar:.2}x"),
            ]);
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table14_linear_layer", &t.render())
}
