//! Paper Fig. 4 (right) / Table 17 — peak decode memory: fp16 cache vs the
//! packed-int4 paged cache, across sequence lengths and batch sizes, for
//! the 7B (MHA) and 70B (GQA) head geometries.  Measured from the actual
//! page-pool accounting of the coordinator's KV-cache manager.  Expected
//! shape: ~3.6-3.9× saving, slightly higher for GQA (fixed overheads
//! amortize), growing with sequence length.

use anyhow::Result;

use quarot::coordinator::kvcache::{PagePool, SeqCache};
use quarot::model::ModelConfig;
use quarot::bench_support::{record, CheckSink};
use quarot::util::bench::Table;
use quarot::util::prng::Rng;

fn cfg(name: &str, n_heads: usize, n_kv: usize, layers: usize) -> ModelConfig {
    ModelConfig {
        name: name.into(), vocab: 512, d_model: n_heads * 128,
        n_layers: layers, n_heads, n_kv_heads: n_kv, d_head: 128,
        d_ff: 4 * n_heads * 128, max_seq: 128, cache_seq: 4096,
        decode_batch: 16, kv_group: 128, rope_theta: 1e4, train_ppl: 0.0,
    }
}

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table17_memory");
    // one-layer-scaled geometries (the paper measures a single block too)
    let models = [cfg("LLAMA2-7B-like (MHA)", 32, 32, 1),
                  cfg("LLAMA2-70B-like (GQA)", 64, 8, 1)];
    // `--check`: short sequences only — the page-pool accounting and the
    // end-of-run leak assert are the point, not the absolute MB
    let grid: &[(usize, [usize; 3])] = if chk.active() {
        &[(1, [64, 128, 256]), (4, [64, 128, 256])]
    } else {
        &[(1, [256, 1024, 4096]), (16, [256, 1024, 2048])]
    };
    let mut t = Table::new(
        "Fig 4R / Table 17 — KV memory: fp16-equiv vs packed-int4 pages",
        &["model", "batch", "seq", "fp16 MB", "int4 MB", "saving"]);
    let mut rng = Rng::new(3);
    for m in &models {
        for &(batch, seqs) in grid {
            for &seq in &seqs {
                let geom = SeqCache::new(m, 4, 0.95, 32).geom();
                let pages_needed =
                    2 * m.n_layers * batch * seq.div_ceil(32) + 64;
                let mut pool = PagePool::new(geom.page_bytes(), pages_needed);
                let mut caches: Vec<SeqCache> = (0..batch)
                    .map(|_| SeqCache::new(m, 4, 0.95, 32))
                    .collect();
                let d = m.d_kv();
                let kt = rng.normal_vec(d);
                let vt = rng.normal_vec(d);
                for c in caches.iter_mut() {
                    for _ in 0..seq {
                        for l in 0..m.n_layers {
                            c.append_layer(&mut pool, l, &kt, &vt, m.kv_group)?;
                        }
                        c.bump();
                    }
                }
                let packed: usize = caches.iter().map(|c| c.bytes()).sum();
                let fp16: usize = caches.iter().map(|c| c.fp16_equiv_bytes()).sum();
                let saving = fp16 as f64 / packed as f64;
                chk.cell("saving", saving)?;
                println!("  {} b={batch} s={seq}: {:.2} MB → {:.2} MB ({saving:.2}x)",
                         m.name, fp16 as f64 / 1e6, packed as f64 / 1e6);
                t.row(vec![m.name.clone(), format!("{batch}"), format!("{seq}"),
                           format!("{:.2}", fp16 as f64 / 1e6),
                           format!("{:.2}", packed as f64 / 1e6),
                           format!("{saving:.2}x")]);
                for c in caches.iter_mut() {
                    c.free(&mut pool);
                }
                assert_eq!(pool.in_use(), 0);
            }
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table17_memory", &t.render())
}
