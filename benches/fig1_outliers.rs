//! Paper Fig. 1 — activation outliers before/after QuaRot, as a bench
//! target (the richer visual version lives in examples/outliers.rs).
//! Expected shape: max/median channel ratio collapses toward ~1.5 after
//! rotation at every site/layer where the baseline shows outliers.

use anyhow::Result;

use quarot::bench_support::{record, Artifacts, CheckSink};
use quarot::eval;
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("fig1_outliers");
    let art = match Artifacts::load("tiny-mha") {
        Ok(a) => a,
        Err(e) if chk.active() => {
            println!("[check] fig1_outliers skipped: {e}");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let base = art.calib(false, 4)?;
    let rot = art.calib(true, 4)?;
    let site_names = ["attn-in", "out-proj-in", "ffn-in", "down-proj-in"];
    let mut t = Table::new(
        "Fig 1 — channel |act| max/median ratio, baseline vs QuaRot",
        &["site", "layer", "baseline", "quarot"]);
    for (b, r) in eval::outlier_stats(&base.amax).iter()
        .zip(eval::outlier_stats(&rot.amax).iter()) {
        chk.cell("baseline ratio", b.ratio as f64)?;
        chk.cell("quarot ratio", r.ratio as f64)?;
        t.row(vec![site_names[b.site].into(), format!("{}", b.layer),
                   format!("{:.2}", b.ratio), format!("{:.2}", r.ratio)]);
    }
    if chk.done() {
        return Ok(());
    }
    record("fig1_outliers", &t.render())
}
