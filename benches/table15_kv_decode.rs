//! Paper Table 15 — decoding one token with a 2047-entry KV cache: FP16 vs
//! INT4-packed cache, across the LLAMA-2 head geometries and batch sizes.
//! Expected shape: int4 loses at batch 1 (quant overhead) and wins once
//! the cache IO dominates (paper: crossover ≈ batch 8-16, up to 1.72×).

use anyhow::Result;

use quarot::attention::{decode_f32, decode_quant, CacheF32, CacheQuant};
use quarot::bench_support::record;
use quarot::util::bench::{bench_auto, Table};
use quarot::util::prng::Rng;

fn main() -> Result<()> {
    let ctx = 2047usize;
    let geoms: &[(usize, usize)] = &[(32, 128), (40, 128), (64, 128)];
    let batches = [1usize, 4, 16];
    let mut t = Table::new(
        "Table 15 — decode w/ 2047-token cache: fp32 vs packed-int4 (ms/token)",
        &["heads x dh", "batch", "fp32", "int4", "ratio"]);
    let mut rng = Rng::new(1);
    for &(h, dh) in geoms {
        // one sequence's caches, reused across the batch (IO volume is what
        // matters; contents are irrelevant to timing)
        let mut kf = CacheF32::new(h, dh, ctx);
        let mut vf = CacheF32::new(h, dh, ctx);
        let mut kq = CacheQuant::new(h, dh, 128.min(dh), 4);
        let mut vq = CacheQuant::new(h, dh, 128.min(dh), 4);
        for _ in 0..ctx {
            let kt = rng.normal_vec(h * dh);
            let vt = rng.normal_vec(h * dh);
            kf.append(&kt);
            vf.append(&vt);
            kq.append(&kt, 0.95);
            vq.append(&vt, 0.95);
        }
        let q: Vec<f32> = rng.normal_vec(h * dh);
        let mut out = vec![0.0f32; h * dh];
        let (mut sc, mut kb, mut s8) = (Vec::new(), Vec::new(), Vec::new());
        for &b in &batches {
            let fp = bench_auto(200.0, || {
                for _ in 0..b {
                    decode_f32(&q, h, &kf, &vf, &mut out, &mut sc);
                }
            });
            let i4 = bench_auto(200.0, || {
                for _ in 0..b {
                    decode_quant(&q, h, &kq, &vq, &mut out, &mut sc,
                                 &mut kb, &mut s8);
                }
            });
            let ratio = fp.median_ms() / i4.median_ms();
            println!("  {h}x{dh} b={b}: fp {:.2}ms i4 {:.2}ms ratio {ratio:.2}",
                     fp.median_ms(), i4.median_ms());
            t.row(vec![format!("{h}x{dh}"), format!("{b}"),
                       format!("{:.2}", fp.median_ms()),
                       format!("{:.2}", i4.median_ms()),
                       format!("{ratio:.2}")]);
        }
    }
    record("table15_kv_decode", &t.render())
}
