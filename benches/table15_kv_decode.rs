//! Paper Table 15 — decoding one token with a 2047-entry KV cache: FP16 vs
//! INT4-packed cache, across the LLAMA-2 head geometries and batch sizes.
//! Expected shape: int4 loses at batch 1 (quant overhead) and wins once
//! the cache IO dominates (paper: crossover ≈ batch 8-16, up to 1.72×).
//!
//! Runs the real batched decode ops behind `ComputeBackend` (batch = the
//! number of sequences per tick), through the process-default backend —
//! `QUAROT_BACKEND=scalar|blocked|threaded|auto` selects the kernels, and
//! `cargo bench decode_backends` prints the per-backend comparison.

use anyhow::Result;

use quarot::attention::{CacheF32, CacheQuant, DecodeF32Seq, DecodeQuantSeq};
use quarot::backend;
use quarot::bench_support::{record, CheckSink};
use quarot::util::bench::{bench_auto, Table};
use quarot::util::prng::Rng;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table15_kv_decode");
    // `--check`: one small geometry with a short cache — exercises the
    // fp32 and packed-int4 batched decode paths, skips the timing sweep
    let ctx = if chk.active() { 127usize } else { 2047 };
    let all_geoms: &[(usize, usize)] = &[(32, 128), (40, 128), (64, 128)];
    let geoms = if chk.active() { &all_geoms[..1] } else { all_geoms };
    let batches: &[usize] = if chk.active() { &[1, 4] } else { &[1, 4, 16] };
    let budget = if chk.active() { 1.0 } else { 200.0 };
    let be = backend::default_backend();
    let mut t = Table::new(
        &format!("Table 15 — decode w/ 2047-token cache: fp32 vs packed-int4 \
                  (ms/token, backend={})", be.name()),
        &["heads x dh", "batch", "fp32", "int4", "ratio"]);
    let mut rng = Rng::new(1);
    for &(h, dh) in geoms {
        // one sequence's caches, shared by every batch slot (IO volume is
        // what matters; contents are irrelevant to timing)
        let mut kf = CacheF32::new(h, dh, ctx);
        let mut vf = CacheF32::new(h, dh, ctx);
        let mut kq = CacheQuant::new(h, dh, 128.min(dh), 4);
        let mut vq = CacheQuant::new(h, dh, 128.min(dh), 4);
        for _ in 0..ctx {
            let kt = rng.normal_vec(h * dh);
            let vt = rng.normal_vec(h * dh);
            kf.append(&kt);
            vf.append(&vt);
            kq.append(&kt, 0.95);
            vq.append(&vt, 0.95);
        }
        let q: Vec<f32> = rng.normal_vec(h * dh);
        for &b in batches {
            let seqs_f: Vec<DecodeF32Seq> = (0..b)
                .map(|_| DecodeF32Seq { q: &q, k: kf.view(), v: vf.view() })
                .collect();
            let seqs_q: Vec<DecodeQuantSeq> = (0..b)
                .map(|_| DecodeQuantSeq { q: &q, k: kq.view(), v: vq.view() })
                .collect();
            let mut out = vec![0.0f32; b * h * dh];
            let fp = bench_auto(budget, || {
                be.decode_f32_batch(&seqs_f, h, &mut out);
            });
            let i4 = bench_auto(budget, || {
                be.decode_quant_batch(&seqs_q, h, &mut out);
            });
            chk.cell("fp32", fp.median_ms())?;
            chk.cell("int4", i4.median_ms())?;
            let ratio = fp.median_ms() / i4.median_ms();
            println!("  {h}x{dh} b={b}: fp {:.2}ms i4 {:.2}ms ratio {ratio:.2}",
                     fp.median_ms(), i4.median_ms());
            t.row(vec![format!("{h}x{dh}"), format!("{b}"),
                       format!("{:.2}", fp.median_ms()),
                       format!("{:.2}", i4.median_ms()),
                       format!("{ratio:.2}")]);
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table15_kv_decode", &t.render())
}
