//! Paper Table 1 — WikiText-2(-proxy) perplexity at W4A4KV4 for the method
//! matrix: FP16 baseline, SmoothQuant, naive RTN (OmniQuant's core without
//! re-training; documented substitution), QUIK-style outlier retention,
//! QuaRot (GPTQ) and QuaRot-128G.  Expected *shape* (paper): baseline <
//! QuaRot ≈ QuaRot-128G < QUIK ≪ SmoothQuant/RTN.

use anyhow::Result;

use quarot::bench_support::{available_models, record, Artifacts, CheckSink};
use quarot::coordinator::runner::{QuantSpec, Variant, WeightQuant};
use quarot::eval;
use quarot::quant::{gptq::GptqCfg, rtn::WeightQuantCfg};
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table1_ppl_4bit");
    let windows = chk.windows();
    let mut t = Table::new(
        "Table 1 — 4-bit (W4A4KV4) perplexity",
        &["method", "model", "ppl"]);
    for model in available_models() {
        let art = Artifacts::load(&model)?;
        let eval_toks = art.corpus.split("eval")?;
        let calib_base = art.calib(false, 4)?;
        let calib_rot = art.calib(true, 4)?;

        let base4 = |w| QuantSpec {
            variant: Variant::Baseline, act_bits: 4, act_clip: 0.9,
            kv_bits: 4, kv_bits_v: 4, kv_clip: 0.95, weights: w,
            outliers: 0, smooth: false,
        };
        let rows: Vec<(&str, QuantSpec, bool)> = vec![
            ("Baseline FP16", QuantSpec::fp16_baseline(), false),
            ("SmoothQuant RTN", QuantSpec {
                smooth: true, ..base4(WeightQuant::Rtn(WeightQuantCfg::rtn(4)))
            }, true),
            ("RTN (no rotation)",
             base4(WeightQuant::Rtn(WeightQuantCfg::rtn(4))), false),
            ("QUIK-like (16 outliers)", QuantSpec {
                outliers: 16,
                ..base4(WeightQuant::Rtn(WeightQuantCfg::rtn(4)))
            }, true),
            ("QuaRot (GPTQ)", QuantSpec {
                weights: WeightQuant::Gptq(GptqCfg::new(4), calib_rot.clone()),
                ..QuantSpec::quarot(4)
            }, false),
            ("QuaRot-128G", QuantSpec {
                weights: WeightQuant::Gptq(GptqCfg::grouped(4, 128),
                                           calib_rot.clone()),
                ..QuantSpec::quarot(4)
            }, false),
        ];
        for (label, spec, needs_base_calib) in rows {
            let stats = if needs_base_calib { Some(&calib_base) } else { None };
            let runner = art.runner_prefill_only(spec, stats)?;
            let p = eval::perplexity(&runner, eval_toks, windows)?;
            chk.cell(label, p)?;
            println!("  [{model}] {label:28} {p:.4}");
            t.row(vec![label.into(), model.clone(), format!("{p:.4}")]);
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table1_ppl_4bit", &t.render())
}
