//! Shared-prefix-cache bench: TTFT and prefill-tokens-saved under
//! 0 / 50 / 100 % shared-prefix traffic.
//!
//! Full mode drives the same request mix against a prefix-cache-enabled
//! engine and a cold (cache-off) twin at the same seed and tabulates
//! hit rate, prefill tokens served from cache, and per-class TTFT —
//! the serving-side complement of the paper's Table 17 memory story
//! (KV-4 pages are ~4× cheaper to keep resident, which is what makes
//! pinning shared prefixes worthwhile).
//!
//! `--check` is the CI acceptance smoke: token streams with the cache
//! on must be **bit-exact** vs the cold path at every traffic mix, a
//! drained session must hold exactly the trie's pinned pages, and a
//! prefix flush must return the pool to zero (no refcount leaks).
//!
//! Like the examples, it self-skips with exit 0 when AOT artifacts are
//! absent, so CI stays green on runners without `make artifacts`.

use anyhow::{anyhow, bail, Result};

use quarot::api::{GenerationParams, LocalSession, SessionConfig};
use quarot::bench_support::{record, Artifacts};
use quarot::cluster::LatencySummary;
use quarot::coordinator::batcher::{GenerationEngine, TOKENS_PER_PAGE};
use quarot::coordinator::prefix::PrefixStats;
use quarot::coordinator::runner::QuantSpec;
use quarot::util::bench::Table;

const MODEL: &str = "tiny-mha";
const SEED: u64 = 21;
const PAGES: usize = 4096;
const N_REQS: usize = 12;
const MAX_NEW: usize = 8;

/// Prompt set with `shared_pct` % of each prompt common to every
/// request (the "system prompt"), unique tails after it.
fn prompts(art: &Artifacts, shared_pct: usize) -> Result<Vec<Vec<u16>>> {
    let eval = art.corpus.split("eval")?;
    let plen = 3 * TOKENS_PER_PAGE;
    if eval.len() < plen * 8 {
        bail!("eval split too short ({} tokens) for {plen}-token prompts",
              eval.len());
    }
    let shared = plen * shared_pct / 100;
    Ok((0..N_REQS)
        .map(|i| {
            let mut p = eval[..shared].to_vec();
            let off = plen * 2 + (i * 31) % (plen * 4);
            p.extend_from_slice(&eval[off..off + plen - shared]);
            p
        })
        .collect())
}

struct Run {
    ttft: LatencySummary,
    stats: PrefixStats,
    streams: Vec<Vec<u16>>,
}

/// Drive the mix sequentially (per-request TTFT stays attributable) and
/// run the leak smoke before returning.
fn run(art: &Artifacts, shared_pct: usize, prefix_pages: usize) -> Result<Run> {
    let runner = art.runner(QuantSpec::quarot(4), None)?;
    let mut engine = GenerationEngine::new(runner, PAGES, SEED);
    engine.set_prefix_cache_pages(prefix_pages);
    let session = LocalSession::new(engine, SessionConfig::default());
    let mut ttfts = Vec::new();
    let mut streams = Vec::new();
    for p in prompts(art, shared_pct)? {
        let out = session
            .submit(GenerationParams::new(p).max_new(MAX_NEW))
            .map_err(|e| anyhow!("{e}"))?
            .wait()?;
        ttfts.push(out.stats.ttft_ms);
        streams.push(out.tokens);
    }
    let stats = session.prefix_stats();
    if session.pool_in_use() != stats.pages_pinned {
        bail!("leak: {} pages in use after drain vs {} pinned by the trie",
              session.pool_in_use(), stats.pages_pinned);
    }
    session.clear_prefix_cache();
    if session.pool_in_use() != 0 {
        bail!("leak: {} pages still allocated after the prefix flush",
              session.pool_in_use());
    }
    Ok(Run { ttft: LatencySummary::of(&ttfts), stats, streams })
}

/// Acceptance: cache-on ≡ cache-off token streams at every mix, plus
/// the leak smoke inside [`run`].
fn check(art: &Artifacts) -> Result<()> {
    for pct in [0usize, 50, 100] {
        let cold = run(art, pct, 0)?;
        let hot = run(art, pct, PAGES / 2)?;
        if cold.streams != hot.streams {
            bail!("{pct}% shared traffic: prefix-cache token streams \
                   diverged from the cold path");
        }
        println!("[check] {pct:3}% shared: {N_REQS} reqs bit-exact, \
                  hit rate {:.0}%, {} prefill tokens saved",
                 hot.stats.hit_rate() * 100.0, hot.stats.hit_tokens);
    }
    Ok(())
}

fn main() -> Result<()> {
    let check_mode = std::env::args().any(|a| a == "--check");
    let art = match Artifacts::load(MODEL) {
        Ok(a) => a,
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            return Ok(());
        }
    };

    if check_mode {
        check(&art)?;
        println!("[check] prefix cache acceptance OK");
        return Ok(());
    }

    let mut t = Table::new(
        "Shared-prefix cache — hit rate, prefill work saved, TTFT by mix",
        &["shared %", "hit %", "toks saved", "ttft ms", "ttft p95",
          "cold ttft ms"]);
    for pct in [0usize, 50, 100] {
        let cold = run(&art, pct, 0)?;
        let hot = run(&art, pct, PAGES / 2)?;
        println!("  [{pct:3}% shared] hit {:.0}%, {} prefill tokens saved, \
                  ttft {:.2} ms (cold {:.2} ms)",
                 hot.stats.hit_rate() * 100.0, hot.stats.hit_tokens,
                 hot.ttft.mean_ms, cold.ttft.mean_ms);
        t.row(vec![
            format!("{pct}"),
            format!("{:.0}", hot.stats.hit_rate() * 100.0),
            format!("{}", hot.stats.hit_tokens),
            format!("{:.2}", hot.ttft.mean_ms),
            format!("{:.2}", hot.ttft.p95_ms),
            format!("{:.2}", cold.ttft.mean_ms),
        ]);
    }
    record("prefix_cache", &t.render())
}
