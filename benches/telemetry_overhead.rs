//! Telemetry overhead bench: decode throughput with the span recorder
//! off vs on, on the identical continuous-batching workload.
//!
//! Full mode drives the same request mix three times — recorder
//! disabled, recorder on at full fidelity, and recorder on with 1-in-8
//! `decode_token` sampling — and reports wall time, decode tokens/sec
//! and spans drained per configuration.  Recording is a bounds check
//! plus a 64-byte copy into a preallocated ring, so the on/off columns
//! should be indistinguishable; the table is the receipt.
//!
//! `--check` is the CI acceptance smoke: a disabled recorder must
//! record nothing, an enabled one must account for every span the
//! lifecycle implies **exactly** (queued / prefill / admitted /
//! finish per request, `decode_token` against the engine's decode-token
//! counter through the sampler, tick phases against `decode_steps`),
//! the engine percentiles must be finite and monotone
//! (p50 ≤ p90 ≤ p99 ≤ p99.9), and the drained ring must shape valid
//! Chrome-trace JSON.
//!
//! Like the other serving benches, it self-skips with exit 0 when AOT
//! artifacts are absent, so CI stays green without `make artifacts`.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use quarot::api::{GenerationParams, LocalSession, SessionConfig};
use quarot::bench_support::{record, Artifacts, CheckSink};
use quarot::coordinator::batcher::{EngineStats, GenerationEngine};
use quarot::coordinator::runner::QuantSpec;
use quarot::telemetry::{chrome_trace_json, Span};
use quarot::util::bench::Table;
use quarot::util::json;

const MODEL: &str = "tiny-mha";
const SEED: u64 = 23;
const PAGES: usize = 4096;
const PROMPT: usize = 16;
/// Ring capacity for the traced runs — sized so the workload can never
/// wrap (wrapping would break the exact span accounting).
const RING: usize = 4096;

struct Run {
    wall_ms: f64,
    spans: Vec<Span>,
    stats: EngineStats,
}

impl Run {
    fn tokens_per_sec(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return 0.0;
        }
        self.stats.decode_tokens as f64 / (self.wall_ms / 1e3)
    }
}

/// Drive `n_reqs` concurrent requests of `max_new` tokens each through
/// a fresh engine at a fixed seed, then drain its span ring.
fn run(art: &Artifacts, n_reqs: usize, max_new: usize, ring: usize,
       sample: u64) -> Result<Run> {
    let runner = art.runner(QuantSpec::quarot(4), None)?;
    let s = LocalSession::new(GenerationEngine::new(runner, PAGES, SEED),
                              SessionConfig::default());
    s.set_trace_buffer(ring);
    s.set_trace_sample(sample);
    let eval = art.corpus.split("eval")?;
    if eval.len() < n_reqs * PROMPT {
        bail!("eval split too short ({} tokens) for {n_reqs} prompts",
              eval.len());
    }
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_reqs)
        .map(|i| {
            let prompt = eval[i * PROMPT..(i + 1) * PROMPT].to_vec();
            s.submit(GenerationParams::new(prompt).max_new(max_new))
                .map_err(|e| anyhow!("{e}"))
        })
        .collect::<Result<_>>()?;
    for h in &handles {
        h.wait()?;
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok(Run { wall_ms, spans: s.drain_spans(), stats: s.stats() })
}

fn count(spans: &[Span], name: &str) -> usize {
    spans.iter().filter(|sp| sp.name == name).count()
}

/// Finiteness + monotonicity gate over one engine histogram's
/// percentile ladder.
fn check_hist(sink: &mut CheckSink, label: &str,
              hist: &quarot::telemetry::Histogram, want_count: u64)
              -> Result<()> {
    if hist.count() != want_count {
        bail!("{label}: {} samples recorded, expected {want_count}",
              hist.count());
    }
    let mut prev = 0.0f64;
    for (q, tag) in [(0.50, "p50"), (0.90, "p90"), (0.99, "p99"),
                     (0.999, "p99.9")] {
        let v = hist.quantile(q);
        sink.cell(&format!("{label} {tag}"), v)?;
        if v + 1e-9 < prev {
            bail!("{label}: {tag} = {v} < previous quantile {prev} — \
                   percentile ladder must be monotone");
        }
        prev = v;
    }
    Ok(())
}

/// Acceptance: exact span accounting on/off/sampled, monotone finite
/// percentiles, valid Chrome-trace shaping.
fn check(art: &Artifacts, sink: &mut CheckSink) -> Result<()> {
    let (n, g) = (4usize, 8usize);

    // recorder disabled: the hot path must record nothing at all
    let off = run(art, n, g, 0, 1)?;
    if !off.spans.is_empty() {
        bail!("disabled recorder drained {} span(s)", off.spans.len());
    }
    sink.cell("off tok/s", off.tokens_per_sec())?;

    // recorder on, full fidelity: every lifecycle span accounted for
    let on = run(art, n, g, RING, 1)?;
    sink.cell("on tok/s", on.tokens_per_sec())?;
    if on.spans.len() >= RING {
        bail!("span ring wrapped — grow RING to keep accounting exact");
    }
    if on.stats.decode_tokens != n * (g - 1) {
        bail!("workload drifted: {} decode tokens, expected {} \
               ({} reqs × {} post-admission tokens)",
              on.stats.decode_tokens, n * (g - 1), n, g - 1);
    }
    let steps = on.stats.decode_steps;
    for (name, want) in [
        ("queued", n),
        ("prefill", n),
        ("admitted", n),
        ("finish:max_tokens", n),
        // the first token of each request lands at admission; every
        // later one is a decode-tick sample with its own span
        ("decode_token", on.stats.decode_tokens),
        ("tick.decode", steps),
        ("tick.sample", steps),
        ("tick.append", steps),
    ] {
        let got = count(&on.spans, name);
        if got != want {
            bail!("span accounting: {got} `{name}` span(s), expected {want}");
        }
    }
    // admit runs on every tick, decode only on ticks with active slots
    if count(&on.spans, "tick.admit") < steps {
        bail!("fewer tick.admit spans than decode ticks");
    }

    // percentile ladders: one TTFT/queue-wait sample per request, one
    // ITL sample per decode token, one tick sample per decode step
    check_hist(sink, "ttft", &on.stats.ttft_hist, n as u64)?;
    check_hist(sink, "itl", &on.stats.itl_hist,
               on.stats.decode_tokens as u64)?;
    check_hist(sink, "queue_wait", &on.stats.queue_wait_hist, n as u64)?;
    check_hist(sink, "tick", &on.stats.tick_hist, steps as u64)?;

    // 1-in-K sampling thins exactly the decode_token stream
    let k = 8u64;
    let sampled = run(art, n, g, RING, k)?;
    sink.cell("sampled tok/s", sampled.tokens_per_sec())?;
    let want = sampled.stats.decode_tokens / k as usize;
    if count(&sampled.spans, "decode_token") != want {
        bail!("1-in-{k} sampling kept {} decode spans, expected {want}",
              count(&sampled.spans, "decode_token"));
    }
    for name in ["queued", "prefill", "admitted", "finish:max_tokens"] {
        if count(&sampled.spans, name) != n {
            bail!("sampling must not thin lifecycle `{name}` spans");
        }
    }

    // the drained ring shapes a valid Chrome-trace document
    let doc = chrome_trace_json(&on.spans, 0);
    let back = json::parse(&json::write(&doc))
        .map_err(|e| anyhow!("trace JSON does not round-trip: {e}"))?;
    let events = back.get("traceEvents").and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("trace JSON lost its traceEvents array"))?;
    if events.len() != on.spans.len() {
        bail!("trace export: {} events from {} spans", events.len(),
              on.spans.len());
    }

    println!("[check] {} spans accounted exactly over {n}×{g} tokens; \
              sampled run kept {want} decode span(s)",
             on.spans.len());
    Ok(())
}

fn main() -> Result<()> {
    let mut sink = CheckSink::new("telemetry_overhead");
    let art = match Artifacts::load(MODEL) {
        Ok(a) => a,
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            return Ok(());
        }
    };

    if sink.active() {
        check(&art, &mut sink)?;
        sink.done();
        return Ok(());
    }

    let (n, g) = (8usize, 32usize);
    let configs: [(&str, usize, u64); 3] = [
        ("tracing off", 0, 1),
        ("tracing on", RING, 1),
        ("on, 1-in-8", RING, 8),
    ];
    let mut t = Table::new(
        "Telemetry overhead — decode throughput, span recorder off vs on",
        &["config", "wall ms", "decode tok/s", "spans drained"]);
    for (label, ring, sample) in configs {
        let r = run(&art, n, g, ring, sample)?;
        println!("  {label:11} {:.1} ms, {:.0} tok/s, {} span(s)",
                 r.wall_ms, r.tokens_per_sec(), r.spans.len());
        t.row(vec![
            label.to_string(),
            format!("{:.1}", r.wall_ms),
            format!("{:.0}", r.tokens_per_sec()),
            format!("{}", r.spans.len()),
        ]);
    }
    record("telemetry_overhead", &t.render())
}
