//! Paper Tables 11/12/13 (Appendix A.8/A.9) — QuaRot on the other model
//! families: the harder-to-quantize LLAMA-3 proxy (`small-mha`, Kronecker
//! H12 FFN), the GQA 70B proxy and the Phi-3 proxy, at RTN/GPTQ ×
//! INT4/6/8.  Expected shape: same orderings as Table 3 on every config.

use anyhow::Result;

use quarot::bench_support::{record, Artifacts, CheckSink};
use quarot::coordinator::runner::{QuantSpec, WeightQuant};
use quarot::eval;
use quarot::quant::gptq::GptqCfg;
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table11_alt_models");
    let windows = chk.windows();
    let mut t = Table::new(
        "Tables 11-13 — alternative architectures (LLAMA-3/GQA/Phi proxies)",
        &["model", "method", "precision", "ppl"]);
    for model in ["small-mha", "tiny-gqa", "phi-proxy"] {
        let art = match Artifacts::load(model) {
            Ok(a) => a,
            Err(_) => continue,
        };
        let eval_toks = art.corpus.split("eval")?;
        let calib_rot = art.calib(true, 2)?;
        let fp = art.runner_prefill_only(QuantSpec::fp16_baseline(), None)?;
        let p = eval::perplexity(&fp, eval_toks, windows)?;
        chk.cell("FP16", p)?;
        t.row(vec![model.into(), "Baseline".into(), "FP16".into(),
                   format!("{p:.4}")]);
        drop(fp);
        for bits in [4u32, 8] {
            for (method, spec) in [
                ("QuaRot-RTN", QuantSpec::quarot(bits)),
                ("QuaRot-GPTQ", QuantSpec {
                    weights: WeightQuant::Gptq(GptqCfg::new(bits), calib_rot.clone()),
                    ..QuantSpec::quarot(bits)
                }),
            ] {
                let runner = art.runner_prefill_only(spec, None)?;
                let p = eval::perplexity(&runner, eval_toks, windows)?;
                chk.cell(method, p)?;
                println!("  [{model}] {method} INT{bits}: {p:.4}");
                t.row(vec![model.into(), method.into(), format!("INT{bits}"),
                           format!("{p:.4}")]);
            }
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table11_alt_models", &t.render())
}
