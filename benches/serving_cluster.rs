//! Sharded serving-cluster bench: per-shard-count throughput/TTFT table
//! over synthetic mixed Interactive/Batch traffic.
//!
//! Full mode sweeps shard counts over the same workload and records
//! per-class mean/p95 TTFT plus aggregate decode throughput —
//! the serving-side scaling twin of the paper's Sec. 5.2 speedups.
//!
//! `--check` is the CI one-rep acceptance smoke (no timing table): on
//! 2 shards, a mixed-priority workload must complete both classes (no
//! starvation) with Interactive arrivals admitted ahead of the *queued*
//! Batch backlog (fair-share TTFT ordering).  The other acceptance
//! property — a 1-shard cluster producing event streams identical to a
//! `LocalSession` — lives in `rust/tests/api_stream.rs`
//! (`one_shard_cluster_matches_local_session`), which CI runs via
//! `cargo test`.
//!
//! Like the examples, it self-skips with exit 0 when AOT artifacts are
//! absent, so CI stays green on runners without `make artifacts`.

use std::sync::Arc;

use anyhow::{bail, Result};

use quarot::api::{GenerationParams, Priority, RequestHandle};
use quarot::bench_support::{drain_class, record, Artifacts};
use quarot::cluster::{ClusterConfig, ClusterService, EngineFactory,
                      LatencySummary};
use quarot::coordinator::batcher::GenerationEngine;
use quarot::coordinator::runner::QuantSpec;
use quarot::util::bench::Table;

const MODEL: &str = "tiny-mha";
const SEED: u64 = 9;
const PAGES: usize = 2048;

fn factory() -> EngineFactory {
    Arc::new(|| {
        let art = Artifacts::load(MODEL)?;
        let runner = art.runner(QuantSpec::quarot(4), None)?;
        Ok(GenerationEngine::new(runner, PAGES, SEED))
    })
}

fn prompts(art: &Artifacts, n: usize, len: usize) -> Result<Vec<Vec<u16>>> {
    let eval = art.corpus.split("eval")?;
    if eval.len() < len {
        bail!("eval split too short ({} tokens) for {len}-token prompts",
              eval.len());
    }
    let span = eval.len().saturating_sub(len).max(1);
    Ok((0..n).map(|i| {
        let off = (i * 17) % span;
        eval[off..off + len].to_vec()
    }).collect())
}

struct RunResult {
    interactive: LatencySummary,
    interactive_tokens: usize,
    batch: LatencySummary,
    batch_tokens: usize,
    /// mean TTFT of the slowest `n_interactive` batch requests — the
    /// queued tail the fair-share scheduler makes interactive jump ahead of
    batch_tail_ttft_ms: f64,
    wall_s: f64,
    tokens_per_sec: f64,
}

/// Mixed workload: a Batch backlog larger than the cluster's slot
/// capacity, then Interactive arrivals that must jump the queued tail.
fn run_workload(art: &Artifacts, shards: usize, n_batch: usize,
                n_interactive: usize, batch_max_new: usize,
                max_new: usize) -> Result<RunResult> {
    let cluster = ClusterService::new(factory(),
                                      ClusterConfig { shards, queue_bound: 256 });
    let bp = prompts(art, n_batch, 8)?;
    let ip = prompts(art, n_interactive, 8)?;
    let t0 = std::time::Instant::now();
    let batch: Vec<RequestHandle> = bp.iter()
        .map(|p| cluster.submit(GenerationParams::new(p.clone())
                                    .max_new(batch_max_new)
                                    .priority(Priority::Batch))
            .map_err(|e| anyhow::anyhow!("{e}")))
        .collect::<Result<_>>()?;
    let interactive: Vec<RequestHandle> = ip.iter()
        .map(|p| cluster.submit(GenerationParams::new(p.clone()).max_new(max_new))
            .map_err(|e| anyhow::anyhow!("{e}")))
        .collect::<Result<_>>()?;

    let i_out = drain_class(&interactive)?;
    let mut b_out = drain_class(&batch)?;
    let wall = t0.elapsed().as_secs_f64();
    let i_sum = LatencySummary::of(&i_out.ttfts);
    let b_sum = LatencySummary::of(&b_out.ttfts);
    // sort explicitly for the tail slice (LatencySummary no longer
    // mutates its input — it reduces through telemetry::Histogram)
    b_out.ttfts.sort_by(|a, b| a.total_cmp(b));
    let tail: &[f64] = &b_out.ttfts[b_out.ttfts.len()
                                        .saturating_sub(n_interactive)..];
    let tail_mean = tail.iter().sum::<f64>() / tail.len().max(1) as f64;
    let tokens = i_out.tokens + b_out.tokens;
    Ok(RunResult {
        interactive: i_sum,
        interactive_tokens: i_out.tokens,
        batch: b_sum,
        batch_tokens: b_out.tokens,
        batch_tail_ttft_ms: tail_mean,
        wall_s: wall,
        tokens_per_sec: tokens as f64 / wall,
    })
}

/// Acceptance check 2: fair-share on 2 shards — no class starves, and
/// interactive arrivals beat the queued batch tail.
fn fairness_check(art: &Artifacts) -> Result<()> {
    // backlog sized well past slot capacity so a queued batch tail exists
    let b = art.runner(QuantSpec::quarot(4), None)?.cfg.decode_batch;
    let n_batch = 2 * 2 * b + 4;
    let n_interactive = 4;
    let r = run_workload(art, 2, n_batch, n_interactive, 24, 6)?;
    if r.interactive_tokens != n_interactive * 6 {
        bail!("interactive class incomplete: {} tokens", r.interactive_tokens);
    }
    if r.batch_tokens != n_batch * 24 {
        bail!("batch class starved: {} of {} tokens",
              r.batch_tokens, n_batch * 24);
    }
    if r.interactive.mean_ms > r.batch_tail_ttft_ms {
        bail!("interactive TTFT ({:.1} ms) did not beat the queued batch \
               tail ({:.1} ms) — fair-share admission is not working",
              r.interactive.mean_ms, r.batch_tail_ttft_ms);
    }
    println!("[check] 2-shard mixed workload: both classes complete; \
              interactive ttft {:.1} ms vs queued-batch tail {:.1} ms",
             r.interactive.mean_ms, r.batch_tail_ttft_ms);
    Ok(())
}

fn main() -> Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    let art = match Artifacts::load(MODEL) {
        Ok(a) => a,
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            return Ok(());
        }
    };

    if check {
        fairness_check(&art)?;
        println!("[check] serving cluster acceptance OK");
        return Ok(());
    }

    let b = art.runner(QuantSpec::quarot(4), None)?.cfg.decode_batch;
    let mut t = Table::new(
        "Serving cluster — mixed Interactive/Batch traffic per shard count",
        &["shards", "tok/s", "wall s", "int ttft ms", "int p95",
          "batch ttft ms", "batch p95"]);
    for shards in [1usize, 2, 4] {
        let n_batch = 2 * shards * b + 4;
        let r = run_workload(&art, shards, n_batch, 6, 32, 8)?;
        println!("  [{shards} shard(s)] {:.1} tok/s, interactive ttft \
                  {:.1}/{:.1} ms, batch ttft {:.1}/{:.1} ms",
                 r.tokens_per_sec, r.interactive.mean_ms,
                 r.interactive.p95_ms, r.batch.mean_ms, r.batch.p95_ms);
        t.row(vec![
            format!("{shards}"),
            format!("{:.1}", r.tokens_per_sec),
            format!("{:.2}", r.wall_s),
            format!("{:.1}", r.interactive.mean_ms),
            format!("{:.1}", r.interactive.p95_ms),
            format!("{:.1}", r.batch.mean_ms),
            format!("{:.1}", r.batch.p95_ms),
        ]);
    }
    record("serving_cluster", &t.render())
}
