//! Paper Table 5 (Appendix A.2) — clipping-ratio ablation: input
//! (activation) clipping and KV-cache clipping swept independently with
//! everything else held in high precision.  Expected shape: a shallow
//! optimum near 0.9 (acts) / 0.95 (KV).

use anyhow::Result;

use quarot::bench_support::{record, Artifacts, CheckSink};
use quarot::coordinator::runner::QuantSpec;
use quarot::eval;
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table5_clipping");
    let windows = chk.windows();
    let art = match Artifacts::load("tiny-mha") {
        Ok(a) => a,
        Err(e) if chk.active() => {
            println!("[check] table5_clipping skipped: {e}");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let eval_toks = art.corpus.split("eval")?;
    let mut t = Table::new("Table 5 — clipping-ratio ablation",
                           &["what", "clip", "ppl"]);
    for clip in [1.0f32, 0.95, 0.9, 0.85] {
        // input quantization only (weights + KV stay high precision)
        let spec = QuantSpec {
            act_bits: 4, act_clip: clip, kv_bits: 16, kv_bits_v: 16,
            weights: quarot::coordinator::runner::WeightQuant::None,
            ..QuantSpec::quarot(4)
        };
        let runner = art.runner_prefill_only(spec, None)?;
        let p = eval::perplexity(&runner, eval_toks, windows)?;
        chk.cell("input quant", p)?;
        println!("  acts clip {clip}: {p:.4}");
        t.row(vec!["input quant".into(), format!("{clip}"), format!("{p:.4}")]);
    }
    for clip in [1.0f32, 0.95, 0.9, 0.85] {
        // KV quantization only
        let spec = QuantSpec {
            act_bits: 0, kv_bits: 4, kv_bits_v: 4, kv_clip: clip,
            weights: quarot::coordinator::runner::WeightQuant::None,
            ..QuantSpec::quarot(4)
        };
        let runner = art.runner_prefill_only(spec, None)?;
        let p = eval::perplexity(&runner, eval_toks, windows)?;
        chk.cell("KV quant", p)?;
        println!("  KV clip {clip}: {p:.4}");
        t.row(vec!["KV quant".into(), format!("{clip}"), format!("{p:.4}")]);
    }
    if chk.done() {
        return Ok(());
    }
    record("table5_clipping", &t.render())
}
