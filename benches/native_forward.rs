//! Native (graph-free) forward-pass serving bench — throughput of the
//! `--executor native` path, side by side with the PJRT graph path
//! where AOT artifacts exist.
//!
//! The native rows need no artifacts: they serve a synthetic-weight
//! model built in memory ([`synthetic_archive`]), so this bench runs —
//! and its `--check` smoke bites — on machines without `make
//! artifacts`.  The `tiny-mha` rows (both executors over the same real
//! weight archive) are artifact-gated and self-skip like the other
//! serving benches.
//!
//! `--check` (CI) pins the scalar backend and asserts the chunked-
//! prefill contract on a cold S-token prompt at chunk budgets
//! 1 / 5 / 64:
//!
//! - identical token streams (chunk 1 IS the old token-at-a-time
//!   suffix loop, so agreement pins the refactor's numerics);
//! - `prefill_chunk_tokens == suffix_prefill_tokens == S`;
//! - `prefill_chunks == ceil(S / chunk)` — the tick-budget acceptance
//!   criterion: an S-token uncached prompt costs ceil(S/chunk) prefill
//!   calls, not S.

use anyhow::{bail, ensure, Result};

use quarot::api::{Priority, QualityTier, Sampling};
use quarot::backend::{self, BackendKind};
use quarot::bench_support::{record, synthetic_archive, Artifacts};
use quarot::coordinator::batcher::{
    EngineStats, GenerationEngine, Request, DEFAULT_PREFILL_CHUNK,
};
use quarot::coordinator::runner::{ExecutorKind, QuantSpec, Runner};
use quarot::forward::weights::canonical_weight_order;
use quarot::model::ModelConfig;
use quarot::util::bench::Table;

const MODEL: &str = "tiny-mha";
const SEED: u64 = 11;

/// Proven-dimension toy config (the same shape the engine-level unit
/// tests serve): MHA→GQA grouping, two layers, hadamard-compatible d_ff.
fn bench_cfg() -> ModelConfig {
    ModelConfig {
        name: "native-bench".into(),
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_head: 4,
        d_ff: 24,
        max_seq: 48,
        cache_seq: 64,
        decode_batch: 2,
        kv_group: 4,
        rope_theta: 1e4,
        train_ppl: 0.0,
    }
}

fn request(prompt: Vec<u16>, max_new: usize) -> Request {
    Request {
        id: 0,
        prompt,
        max_new_tokens: max_new,
        sampling: Sampling::Greedy,
        stop_token: None,
        priority: Priority::Interactive,
        deadline_ms: None,
        tier: QualityTier::Kv4,
        session: None,
    }
}

fn prompt_tokens(vocab: usize, len: usize, salt: usize) -> Vec<u16> {
    (0..len).map(|i| ((i * 7 + salt * 13) % (vocab - 1)) as u16).collect()
}

/// One cold workload on a fresh engine; returns the completed token
/// streams (request-submission order) and the final counters.
fn run_workload(runner: Runner, chunk: usize, n_reqs: usize, prompt_len: usize,
                max_new: usize) -> Result<(Vec<Vec<u16>>, EngineStats)> {
    let vocab = runner.cfg.vocab;
    let mut eng = GenerationEngine::new(runner, 256, 9);
    eng.set_prefill_chunk(chunk);
    let mut ids = Vec::new();
    for r in 0..n_reqs {
        ids.push(eng.submit(request(prompt_tokens(vocab, prompt_len, r),
                                    max_new)));
    }
    let mut done = eng.run_to_completion()?;
    ensure!(done.len() == n_reqs,
            "expected {n_reqs} completions, got {}", done.len());
    done.sort_by_key(|c| ids.iter().position(|&i| i == c.id));
    Ok((done.into_iter().map(|c| c.tokens).collect(), eng.stats.clone()))
}

/// `--check`: chunk-size invariance + ceil(S/chunk) budget accounting
/// on the scalar backend (bit-stable across forward shapes).
fn check_chunk_contract() -> Result<()> {
    let cfg = bench_cfg();
    let weights = synthetic_archive(&cfg, SEED)?;
    const S: usize = 23;
    let mut streams: Vec<Vec<Vec<u16>>> = Vec::new();
    for &chunk in &[1usize, 5, 64] {
        let runner = Runner::new_native_with_backend(
            &cfg, &canonical_weight_order(), &weights, QuantSpec::quarot(4),
            None, backend::make(BackendKind::Scalar))?;
        let (tokens, st) = run_workload(runner, chunk, 1, S, 8)?;
        ensure!(st.suffix_prefill_tokens == S,
                "chunk {chunk}: cold suffix must be the whole {S}-token \
                 prompt, counted {}", st.suffix_prefill_tokens);
        ensure!(st.prefill_chunk_tokens == st.suffix_prefill_tokens,
                "chunk {chunk}: chunk-token counter diverged from suffix \
                 counter ({} vs {})",
                st.prefill_chunk_tokens, st.suffix_prefill_tokens);
        let want = S.div_ceil(chunk);
        ensure!(st.prefill_chunks == want,
                "chunk {chunk}: {S}-token suffix must cost ceil({S}/{chunk}) \
                 = {want} prefill calls, counted {}", st.prefill_chunks);
        println!("[check] chunk {chunk:>2}: {} prefill call(s) for the \
                  {S}-token cold prompt", st.prefill_chunks);
        streams.push(tokens);
    }
    if streams[1..].iter().any(|s| *s != streams[0]) {
        bail!("chunked prefill is not chunk-size invariant: token streams \
               diverged across budgets 1/5/64");
    }
    println!("[check] native_forward OK (chunk-size-invariant streams, \
              exact ceil(S/chunk) budget accounting)");
    Ok(())
}

/// Row of the throughput table from one workload's engine counters.
fn row(t: &mut Table, executor: &str, model: &str, chunk: usize,
       st: &EngineStats) {
    let pf_tps = st.suffix_prefill_tokens as f64
        / (st.total_prefill_ms / 1e3).max(1e-9);
    let dec_tps = st.decode_tokens as f64
        / (st.total_decode_ms / 1e3).max(1e-9);
    let ttft = st.ttft_sum_ms / (st.ttft_count as f64).max(1.0);
    t.row(vec![
        executor.into(),
        model.into(),
        format!("{chunk}"),
        format!("{pf_tps:.0}"),
        format!("{dec_tps:.0}"),
        format!("{ttft:.2}"),
    ]);
}

fn main() -> Result<()> {
    if std::env::args().any(|a| a == "--check") {
        return check_chunk_contract();
    }

    let mut t = Table::new(
        "Serving throughput by executor — chunked prefill + batched decode",
        &["executor", "model", "chunk", "prefill tok/s", "decode tok/s",
          "avg ttft ms"]);

    // Native rows on the synthetic archive: always runnable.
    let cfg = bench_cfg();
    let weights = synthetic_archive(&cfg, SEED)?;
    for &chunk in &[1usize, 8, DEFAULT_PREFILL_CHUNK] {
        let runner = Runner::new_native_from_parts(
            &cfg, &canonical_weight_order(), &weights, QuantSpec::quarot(4),
            None)?;
        let (_, st) = run_workload(runner, chunk, 8, 24, 16)?;
        row(&mut t, "native", "synthetic", chunk, &st);
    }

    // Real-archive rows, both executors, artifact-gated self-skip.
    match Artifacts::load(MODEL) {
        Ok(art) => {
            for kind in [ExecutorKind::Pjrt, ExecutorKind::Native] {
                let runner = art.runner_kind(kind, QuantSpec::quarot(4),
                                             None)?;
                let (_, st) = run_workload(runner, DEFAULT_PREFILL_CHUNK,
                                           8, 24, 16)?;
                row(&mut t, kind.name(), MODEL, DEFAULT_PREFILL_CHUNK, &st);
            }
        }
        Err(_) => eprintln!(
            "[skip] {MODEL} artifacts missing — run `make artifacts` for \
             the real-archive executor comparison"),
    }

    record("native_forward", &t.render())
}
