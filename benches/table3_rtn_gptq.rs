//! Paper Table 3 / Table 9 — QuaRot-RTN vs QuaRot-GPTQ at INT4/6/8.
//! Expected shape: INT8 ≈ lossless for both; at INT4 GPTQ < RTN, with the
//! gap shrinking as the model grows.

use anyhow::Result;

use quarot::bench_support::{available_models, record, Artifacts, CheckSink};
use quarot::coordinator::runner::{QuantSpec, WeightQuant};
use quarot::eval;
use quarot::quant::gptq::GptqCfg;
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table3_rtn_gptq");
    let windows = chk.windows();
    let mut t = Table::new(
        "Table 3/9 — QuaRot RTN vs GPTQ across precisions",
        &["model", "method", "precision", "ppl"]);
    for model in available_models() {
        let art = Artifacts::load(&model)?;
        let eval_toks = art.corpus.split("eval")?;
        let calib_rot = art.calib(true, 4)?;
        {
            let fp = art.runner_prefill_only(QuantSpec::fp16_baseline(), None)?;
            let p = eval::perplexity(&fp, eval_toks, windows)?;
            chk.cell("FP16", p)?;
            t.row(vec![model.clone(), "Baseline".into(), "FP16".into(),
                       format!("{p:.4}")]);
            println!("  [{model}] FP16 {p:.4}");
        }
        for bits in [4u32, 6, 8] {
            for (method, spec) in [
                ("QuaRot-RTN", QuantSpec::quarot(bits)),
                ("QuaRot-GPTQ", QuantSpec {
                    weights: WeightQuant::Gptq(GptqCfg::new(bits), calib_rot.clone()),
                    ..QuantSpec::quarot(bits)
                }),
            ] {
                let runner = art.runner_prefill_only(spec, None)?;
                let p = eval::perplexity(&runner, eval_toks, windows)?;
                chk.cell(method, p)?;
                println!("  [{model}] {method} INT{bits} {p:.4}");
                t.row(vec![model.clone(), method.into(), format!("INT{bits}"),
                           format!("{p:.4}")]);
            }
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table3_rtn_gptq", &t.render())
}
