//! Multi-turn chat bench: per-turn TTFT and prefill-tokens-saved for
//! session-resident conversations vs cold concatenated-history replay.
//!
//! Full mode drives a round-robin trace of chat sessions (turn 1 of
//! every session, then turn 2, ...) against a session-enabled engine
//! and replays the identical conversations as cold full-history
//! resubmissions on a twin engine at the same seed.  The donated-chain
//! graft keeps chat prefill work per turn roughly constant while the
//! replay prefill grows with the history, which is the serving-side
//! payoff of KV-4 pages being cheap enough to keep resident between
//! turns (the paper's Table 17 memory story).
//!
//! `--check` is the CI acceptance smoke: chat token streams must be
//! **bit-exact** vs the cold replay at every turn, the donation gauge
//! must equal the exact resident history on every turn ≥ 2 (tail-page
//! donation makes the savings token-exact, not page-rounded), the session
//! gauges must partition the trace exactly, and a budget shrink plus
//! trie flush must return the pool to zero (no pin/refcount leaks).
//!
//! Like the examples, it self-skips with exit 0 when AOT artifacts are
//! absent, so CI stays green on runners without `make artifacts`.

use anyhow::{anyhow, bail, Result};

use quarot::api::{GenerationParams, LocalSession, SessionConfig};
use quarot::bench_support::{record, Artifacts};
use quarot::coordinator::batcher::{GenerationEngine, TOKENS_PER_PAGE};
use quarot::coordinator::runner::QuantSpec;
use quarot::util::bench::Table;

const MODEL: &str = "tiny-mha";
const SEED: u64 = 19;
const PAGES: usize = 4096;
const N_SESSIONS: usize = 3;
const N_TURNS: usize = 3;
const MAX_NEW: usize = 8;

/// Per-session turn texts: disjoint first-turn pages (no cross-session
/// trie sharing muddies the donation accounting), short follow-ups.
fn trace(art: &Artifacts) -> Result<Vec<Vec<Vec<u16>>>> {
    let eval = art.corpus.split("eval")?;
    let tpp = TOKENS_PER_PAGE;
    if eval.len() < 16 * tpp {
        bail!("eval split too short ({} tokens) for the chat trace",
              eval.len());
    }
    Ok((0..N_SESSIONS)
        .map(|i| {
            (0..N_TURNS)
                .map(|k| {
                    if k == 0 {
                        eval[i * 2 * tpp..i * 2 * tpp + tpp].to_vec()
                    } else {
                        let off = 8 * tpp + (i * N_TURNS + k) * 8;
                        eval[off..off + 8].to_vec()
                    }
                })
                .collect()
        })
        .collect())
}

/// Tokens a session's turn-k admission grafts from the donated chain:
/// the previous turn's effective prompt plus its generated tokens bar
/// the final sampled one — token-exact, NOT page-rounded, because
/// retirement donates the partially-filled tail page alongside the full
/// ones and the next turn grafts it by copy (0 on turn 1).
fn expected_saved(turn_lens: &[usize]) -> usize {
    let mut hist = 0usize; // history length entering the turn
    let mut prev_prompt = 0usize; // previous turn's effective prompt
    let mut saved = 0usize;
    for (k, &t) in turn_lens.iter().enumerate() {
        let prompt = hist + t;
        if k > 0 {
            saved += prev_prompt + MAX_NEW - 1;
        }
        prev_prompt = prompt;
        hist = prompt + MAX_NEW;
    }
    saved
}

struct Run {
    /// ttft_by_turn[k] = TTFTs of every session's turn k
    ttft_by_turn: Vec<Vec<f64>>,
    /// streams[i][k] = session i's turn-k generated tokens
    streams: Vec<Vec<Vec<u16>>>,
}

/// Chat path: one engine, `N_SESSIONS` live sessions driven round-robin
/// (all turn-1 requests, then all turn-2, ...), history server-side.
fn run_chat(art: &Artifacts, sessions: &LocalSession) -> Result<Run> {
    let trace = trace(art)?;
    let mut sids: Vec<Option<u64>> = vec![None; N_SESSIONS];
    let mut ttft_by_turn = vec![Vec::new(); N_TURNS];
    let mut streams = vec![Vec::new(); N_SESSIONS];
    for k in 0..N_TURNS {
        for i in 0..N_SESSIONS {
            let p = GenerationParams::new(trace[i][k].clone()).max_new(MAX_NEW);
            let p = match sids[i] {
                None => p.new_session(),
                Some(id) => p.resume_session(id),
            };
            let out = sessions.submit(p).map_err(|e| anyhow!("{e}"))?.wait()?;
            sids[i] = Some(out.stats.session
                .ok_or_else(|| anyhow!("chat turn lost its session id"))?);
            ttft_by_turn[k].push(out.stats.ttft_ms);
            streams[i].push(out.tokens);
        }
    }
    Ok(Run { ttft_by_turn, streams })
}

/// Replay path: a cold twin (prefix cache off) resubmits each turn as
/// the full concatenated history — what every turn would cost without
/// the session subsystem.
fn run_replay(art: &Artifacts) -> Result<Run> {
    let runner = art.runner(QuantSpec::quarot(4), None)?;
    let mut engine = GenerationEngine::new(runner, PAGES, SEED);
    engine.set_prefix_cache_pages(0);
    let s = LocalSession::new(engine, SessionConfig::default());
    let trace = trace(art)?;
    let mut hists: Vec<Vec<u16>> = vec![Vec::new(); N_SESSIONS];
    let mut ttft_by_turn = vec![Vec::new(); N_TURNS];
    let mut streams = vec![Vec::new(); N_SESSIONS];
    for k in 0..N_TURNS {
        for i in 0..N_SESSIONS {
            hists[i].extend_from_slice(&trace[i][k]);
            let out = s
                .submit(GenerationParams::new(hists[i].clone()).max_new(MAX_NEW))
                .map_err(|e| anyhow!("{e}"))?
                .wait()?;
            hists[i].extend_from_slice(&out.tokens);
            ttft_by_turn[k].push(out.stats.ttft_ms);
            streams[i].push(out.tokens);
        }
    }
    Ok(Run { ttft_by_turn, streams })
}

fn chat_session(art: &Artifacts) -> Result<LocalSession> {
    let runner = art.runner(QuantSpec::quarot(4), None)?;
    Ok(LocalSession::new(GenerationEngine::new(runner, PAGES, SEED),
                         SessionConfig::default()))
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// Acceptance: bit-exact chat vs replay, exact donation savings on
/// turns ≥ 2, exact gauge partitions, and the eviction + flush leak
/// smoke.
fn check(art: &Artifacts) -> Result<()> {
    let s = chat_session(art)?;
    let chat = run_chat(art, &s)?;
    let replay = run_replay(art)?;
    if chat.streams != replay.streams {
        bail!("chat token streams diverged from cold full-history replay");
    }

    let trace = trace(art)?;
    let expect: usize = trace.iter()
        .map(|turns| {
            let lens: Vec<usize> = turns.iter().map(|t| t.len()).collect();
            expected_saved(&lens)
        })
        .sum();
    if expect == 0 {
        bail!("trace must accrue donation savings on turns >= 2");
    }
    let st = s.stats();
    if st.session_prefill_tokens_saved != expect {
        bail!("donation gauge {} != exact resident history {expect}",
              st.session_prefill_tokens_saved);
    }
    if st.session_turns != N_SESSIONS * N_TURNS {
        bail!("session_turns {} != trace turns {}", st.session_turns,
              N_SESSIONS * N_TURNS);
    }
    if s.sessions_live() != N_SESSIONS {
        bail!("sessions_live {} != {N_SESSIONS}", s.sessions_live());
    }

    // leak smoke: budget shrink evicts + unpins, flush returns the pool
    s.set_session_budget(1);
    if s.sessions_live() != 1 {
        bail!("budget shrink must evict down to 1 live session");
    }
    s.set_session_budget(0);
    if s.sessions_live() != 0 {
        bail!("budget 0 must evict every session");
    }
    s.clear_prefix_cache();
    if s.pool_in_use() != 0 {
        bail!("leak: {} pages still allocated after eviction + flush",
              s.pool_in_use());
    }
    println!("[check] {N_SESSIONS}×{N_TURNS} chat trace bit-exact, \
              {expect} prefill tokens saved, pools drained");
    Ok(())
}

fn main() -> Result<()> {
    let check_mode = std::env::args().any(|a| a == "--check");
    let art = match Artifacts::load(MODEL) {
        Ok(a) => a,
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            return Ok(());
        }
    };

    if check_mode {
        check(&art)?;
        println!("[check] session chat acceptance OK");
        return Ok(());
    }

    let s = chat_session(&art)?;
    let chat = run_chat(&art, &s)?;
    let replay = run_replay(&art)?;
    let st = s.stats();

    let mut t = Table::new(
        "Multi-turn chat — per-turn TTFT, chat (donated KV) vs cold replay",
        &["turn", "chat ttft ms", "replay ttft ms", "speedup"]);
    for k in 0..N_TURNS {
        let c = mean(&chat.ttft_by_turn[k]);
        let r = mean(&replay.ttft_by_turn[k]);
        println!("  [turn {}] chat ttft {c:.2} ms vs replay {r:.2} ms",
                 k + 1);
        t.row(vec![
            format!("{}", k + 1),
            format!("{c:.2}"),
            format!("{r:.2}"),
            format!("{:.2}x", if c > 0.0 { r / c } else { 0.0 }),
        ]);
    }
    println!("  {} sessions × {} turns: {} prefill tokens saved by \
              generated-token donation",
             N_SESSIONS, N_TURNS, st.session_prefill_tokens_saved);
    record("session_chat", &t.render())
}
