//! Paper Table 6 (Appendix A.3) — KV-cache precision grid: K bits × V bits
//! with everything else FP16.  Expected shape: keys more sensitive than
//! values (K3V4 worse than K4V3... actually paper: K4V3 better than K3V4),
//! graceful down to 3 bits, sharp cliff at K2.

use anyhow::Result;

use quarot::bench_support::{record, Artifacts, CheckSink};
use quarot::coordinator::runner::{QuantSpec, WeightQuant};
use quarot::eval;
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table6_kv_bits");
    let windows = chk.windows();
    let mut t = Table::new("Table 6 — KV-cache bit grid (group=head_dim, asym)",
                           &["K bits", "V bits", "model", "ppl"]);
    for model in ["tiny-mha", "tiny-gqa"] {
        let art = match Artifacts::load(model) {
            Ok(a) => a,
            Err(_) => continue,
        };
        let eval_toks = art.corpus.split("eval")?;
        for (kb, vb) in [(16u32, 16u32), (4, 4), (4, 3), (4, 2),
                         (3, 4), (3, 3), (3, 2), (2, 4), (2, 2)] {
            let spec = QuantSpec {
                act_bits: 0, kv_bits: kb, kv_bits_v: vb, kv_clip: 0.95,
                weights: WeightQuant::None,
                ..QuantSpec::quarot(4)
            };
            let runner = art.runner_prefill_only(spec, None)?;
            let p = eval::perplexity(&runner, eval_toks, windows)?;
            // the K2 rows are *expected* to fall off a cliff (possibly
            // to inf) — only the graceful region gates the smoke
            if kb >= 3 && vb >= 3 {
                chk.cell("kv grid", p)?;
            }
            println!("  [{model}] K{kb} V{vb}: {p:.4}");
            t.row(vec![format!("{kb}"), format!("{vb}"), model.into(),
                       format!("{p:.4}")]);
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table6_kv_bits", &t.render())
}
