//! Paper Table 7 (Appendix A.4) — weight-only quantization (A16, KV16):
//! RTN/GPTQ at W4/W3/W2 with and without the QuaRot rotation.  Expected
//! shape: rotation helps both quantizers at every width; W2 only survives
//! with QuaRot+GPTQ.

use anyhow::Result;

use quarot::bench_support::{record, Artifacts, CheckSink};
use quarot::coordinator::runner::{QuantSpec, Variant, WeightQuant};
use quarot::eval;
use quarot::quant::{gptq::GptqCfg, rtn::WeightQuantCfg};
use quarot::util::bench::Table;

fn main() -> Result<()> {
    let mut chk = CheckSink::new("table7_weight_only");
    let windows = chk.windows();
    let art = match Artifacts::load("tiny-mha") {
        Ok(a) => a,
        Err(e) if chk.active() => {
            println!("[check] table7_weight_only skipped: {e}");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let eval_toks = art.corpus.split("eval")?;
    let calib_base = art.calib(false, 4)?;
    let calib_rot = art.calib(true, 4)?;

    let mut t = Table::new("Table 7 — weight-only quantization (A16KV16)",
                           &["method", "W bits", "ppl"]);
    let weight_only = |variant: Variant, w: WeightQuant| QuantSpec {
        variant, act_bits: 0, act_clip: 1.0, kv_bits: 16, kv_bits_v: 16,
        kv_clip: 1.0, weights: w, outliers: 0, smooth: false,
    };
    let p_base = {
        let fp = art.runner_prefill_only(QuantSpec::fp16_baseline(), None)?;
        let p = eval::perplexity(&fp, eval_toks, windows)?;
        chk.cell("Baseline", p)?;
        t.row(vec!["Baseline".into(), "-".into(), format!("{p:.4}")]);
        p
    };
    for bits in [4u32, 3, 2] {
        let rows: Vec<(&str, QuantSpec)> = vec![
            ("RTN", weight_only(Variant::Baseline,
                WeightQuant::Rtn(WeightQuantCfg::asymmetric(bits)))),
            ("GPTQ", weight_only(Variant::Baseline,
                WeightQuant::Gptq(GptqCfg::new(bits), calib_base.clone()))),
            ("QuaRot-RTN", weight_only(Variant::Quarot,
                WeightQuant::Rtn(WeightQuantCfg::asymmetric(bits)))),
            ("QuaRot-GPTQ", weight_only(Variant::Quarot,
                WeightQuant::Gptq(GptqCfg::new(bits), calib_rot.clone()))),
        ];
        for (label, spec) in rows {
            let runner = art.runner_prefill_only(spec, None)?;
            let p = eval::perplexity(&runner, eval_toks, windows)?;
            // W3/W2 without rotation are *allowed* to blow up (the
            // paper prints Inf there); only W4 gates the smoke
            if bits == 4 {
                chk.cell(label, p)?;
            }
            // the paper prints "Inf" for catastrophic (>100) ppl; our scale
            // is ~p_base, so use a relative blow-up threshold instead
            let shown = if p > 20.0 * p_base { "Inf".to_string() }
                        else { format!("{p:.4}") };
            println!("  {label:12} W{bits}: {shown}");
            t.row(vec![label.into(), format!("{bits}"), shown]);
        }
    }
    if chk.done() {
        return Ok(());
    }
    record("table7_weight_only", &t.render())
}
