//! Offline stub of the `xla` PJRT bindings.
//!
//! The build container has no crates.io access and no PJRT plugin, so this
//! vendored crate provides the exact type/method surface that
//! `quarot::runtime::engine` compiles against.  Every entry point returns
//! [`Error::Unavailable`]: the crate builds and links, `cargo test` runs the
//! (artifact-gated) integration suite, and anything that actually needs a
//! compiled HLO graph fails with a clear message instead of at link time.
//!
//! Swapping this for the real bindings is a one-line change in the root
//! `Cargo.toml` (point the `xla` dependency at the real crate); no source
//! in `rust/src` references this stub directly.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// The PJRT runtime is not present in this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "PJRT runtime unavailable in this build (xla stub): {what}"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Parsed HLO module (stub: never constructed).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// Device buffer handle (stub: never constructed).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub: never constructed).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Host-side literal (stub: never constructed).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }
}
