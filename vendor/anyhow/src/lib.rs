//! Offline drop-in subset of the `anyhow` API.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides exactly the surface the workspace uses: [`Error`], [`Result`],
//! the [`Context`] extension trait (on `Result` and `Option`), and the
//! [`anyhow!`] / [`bail!`] macros.  Error values carry a plain context
//! stack (no backtraces, no downcasting) — enough for CLI diagnostics and
//! `?`-conversion from any `std::error::Error`.
//!
//! Mirrors anyhow's coherence trick: `Error` deliberately does **not**
//! implement `std::error::Error`, which is what lets the blanket
//! `From<E: std::error::Error>` impl and the `Context` impls coexist.

use std::fmt;

/// Dynamic error: a stack of context messages, innermost first.
pub struct Error {
    stack: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { stack: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.stack.push(context.to_string());
        self
    }

    /// Context frames, outermost first (like anyhow's `chain()`).
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        self.stack.iter().rev().map(|s| s.as_str())
    }

    /// The outermost (most recently attached) message.
    pub fn root_message(&self) -> &str {
        self.stack.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.root_message())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        let mut causes = self.stack.iter().rev().skip(1).peekable();
        if causes.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut stack = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            stack.insert(0, s.to_string());
            src = s.source();
        }
        Error { stack }
    }
}

mod ext {
    use super::*;

    pub trait StdError {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(ext::StdError::ext_context(e, context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(ext::StdError::ext_context(e, f())),
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tt:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($tt)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(concat!(
                "condition failed: ", stringify!($cond))));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening archive").unwrap_err();
        assert_eq!(e.to_string(), "opening archive");
        assert!(format!("{e:?}").contains("missing"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("field {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "field x");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn failing() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(failing().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn ensure_gates_on_condition() {
        fn inner(x: usize) -> Result<()> {
            ensure!(x < 10, "too big: {x}");
            ensure!(x != 5);
            Ok(())
        }
        assert!(inner(3).is_ok());
        assert_eq!(inner(30).unwrap_err().to_string(), "too big: 30");
        assert!(inner(5).unwrap_err().to_string().contains("x != 5"));
    }

    #[test]
    fn chained_context_stacks() {
        let e = Error::msg("inner").context("mid").context("outer");
        assert_eq!(e.to_string(), "outer");
        let frames: Vec<&str> = e.chain().collect();
        assert_eq!(frames, vec!["outer", "mid", "inner"]);
    }
}
