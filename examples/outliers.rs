//! Figure 1 reproduction: distribution of activations at the FFN input,
//! before vs after QuaRot's rotation — the visual core of the paper.
//!
//! Prints per-site/per-layer channel max-to-median ratios plus an ASCII
//! histogram of channel |activation| maxima for the first layer.
//!
//! Run: `cargo run --release --example outliers`.

use anyhow::Result;

use quarot::bench_support::{record, Artifacts};
use quarot::eval;
use quarot::util::bench::Table;
use quarot::util::cli::Args;

fn histogram(vals: &[f32], buckets: usize) -> String {
    let mx = vals.iter().fold(0.0f32, |m, &v| m.max(v));
    let mut counts = vec![0usize; buckets];
    for &v in vals {
        let b = ((v / mx) * (buckets as f32 - 1.0)) as usize;
        counts[b.min(buckets - 1)] += 1;
    }
    let peak = *counts.iter().max().unwrap();
    counts.iter().enumerate().map(|(i, &c)| {
        let bar = "#".repeat((c * 40 / peak.max(1)).max(usize::from(c > 0)));
        format!("{:6.2}-{:6.2} | {bar} {c}",
                mx * i as f32 / buckets as f32,
                mx * (i + 1) as f32 / buckets as f32)
    }).collect::<Vec<_>>().join("\n")
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.str_or("model", "tiny-mha");
    let art = Artifacts::load(&model)?;
    let windows = args.usize_or("windows", 4);

    println!("[outliers] collecting activation stats (baseline)...");
    let base = art.calib(false, windows)?;
    println!("[outliers] collecting activation stats (rotated)...");
    let rot = art.calib(true, windows)?;

    let mut out = String::new();
    let site_names = ["attn-in", "out-proj-in", "ffn-in", "down-proj-in"];
    let mut t = Table::new(
        "Fig.1 — per-channel |act| max/median ratio (outliers ⇔ ratio ≫ 1)",
        &["site", "layer", "baseline", "quarot", "reduction"]);
    for (b, r) in eval::outlier_stats(&base.amax).iter()
        .zip(eval::outlier_stats(&rot.amax).iter()) {
        t.row(vec![
            site_names[b.site].into(),
            format!("{}", b.layer),
            format!("{:.2}", b.ratio),
            format!("{:.2}", r.ratio),
            format!("{:.1}×", b.ratio / r.ratio.max(1e-6)),
        ]);
    }
    out.push_str(&t.render());

    out.push_str("\nchannel |act| maxima, FFN input, layer 0 — BASELINE:\n");
    out.push_str(&histogram(&base.amax[2][0], 12));
    out.push_str("\n\nchannel |act| maxima, FFN input, layer 0 — QUAROT:\n");
    out.push_str(&histogram(&rot.amax[2][0], 12));
    out.push('\n');
    record("fig1_outliers", &out)?;
    Ok(())
}
