//! Quantization-scheme explorer: sweep a handful of schemes over one model
//! and print paper-style ppl rows — the workflow a practitioner adopting
//! QuaRot would actually run on their own checkpoint.
//!
//! Run: `cargo run --release --example quantize_eval [-- --model tiny-mha]`.

use anyhow::Result;

use quarot::bench_support::{eval_windows, record, Artifacts};
use quarot::coordinator::runner::{QuantSpec, Variant, WeightQuant};
use quarot::eval;
use quarot::quant::{gptq::GptqCfg, rtn::WeightQuantCfg};
use quarot::util::bench::Table;
use quarot::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.str_or("model", "tiny-mha");
    let art = Artifacts::load(&model)?;
    let windows = args.usize_or("windows", eval_windows());
    let eval_toks = art.corpus.split("eval")?;

    println!("[quantize_eval] calibrating (rotated space, for GPTQ)...");
    let stats_rot = art.calib(true, 4)?;

    let rows: Vec<(&str, QuantSpec)> = vec![
        ("FP16 baseline", QuantSpec::fp16_baseline()),
        ("RTN W4A4KV4 (no rotation)", QuantSpec {
            variant: Variant::Baseline,
            act_bits: 4, act_clip: 0.9, kv_bits: 4, kv_bits_v: 4, kv_clip: 0.95,
            weights: WeightQuant::Rtn(WeightQuantCfg::rtn(4)),
            outliers: 0, smooth: false,
        }),
        ("QuaRot-RTN W4A4KV4", QuantSpec::quarot(4)),
        ("QuaRot-GPTQ W4A4KV4", QuantSpec {
            weights: WeightQuant::Gptq(GptqCfg::new(4), stats_rot.clone()),
            ..QuantSpec::quarot(4)
        }),
        ("QuaRot-GPTQ-128G", QuantSpec {
            weights: WeightQuant::Gptq(GptqCfg::grouped(4, 128), stats_rot.clone()),
            ..QuantSpec::quarot(4)
        }),
        ("QuaRot-RTN W8A8KV8", QuantSpec::quarot(8)),
    ];

    let mut t = Table::new(
        &format!("quantize_eval — {model} ({windows} eval windows)"),
        &["scheme", "ppl"]);
    for (label, spec) in rows {
        let runner = art.runner(spec, Some(&stats_rot))?;
        let p = eval::perplexity(&runner, eval_toks, windows)?;
        println!("  {label:32} {p:.4}");
        t.row(vec![label.into(), format!("{p:.4}")]);
    }
    record("quantize_eval", &t.render())?;
    Ok(())
}
