//! End-to-end serving validation (the required full-system driver).
//!
//! Boots the complete stack — AOT-compiled QuaRot-INT4 graphs, paged
//! quantized KV cache, continuous batcher, TCP server speaking the v2
//! event-frame protocol — and exercises it three ways:
//!
//! 1. a batch of concurrent clients streaming token events and reporting
//!    per-request latency + aggregate throughput,
//! 2. one client interleaving two requests on a single connection and
//!    **cancelling** one mid-generation (pages must return to the pool,
//!    every stream must end in exactly one terminal event),
//! 3. held-out perplexity of the served INT4 model next to f32.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example serve_e2e [-- --requests 12]`.

use anyhow::Result;

use quarot::api::{FinishReason, GenerationEvent, GenerationParams};
use quarot::bench_support::{record, Artifacts};
use quarot::coordinator::batcher::GenerationEngine;
use quarot::coordinator::runner::QuantSpec;
use quarot::eval;
use quarot::server::{serve, Client, DEFAULT_QUEUE_BOUND};
use quarot::util::bench::Table;
use quarot::util::cli::Args;
use quarot::util::prng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.str_or("model", "tiny-mha");
    let n_requests = args.usize_or("requests", 10);
    let max_new = args.usize_or("max-new", 24);

    let art = match Artifacts::load(&model) {
        Ok(a) => a,
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            return Ok(());
        }
    };

    println!("[e2e] starting server with QuaRot-INT4 engine ({model})...");
    let m2 = model.clone();
    let handle = serve(
        move || {
            let art = Artifacts::load(&m2)?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 2048, 7))
        },
        0,
        DEFAULT_QUEUE_BOUND,
    )?;
    let port = handle.port;

    // build prompts from held-out data
    let eval_toks = art.corpus.split("eval")?;
    let mut rng = Rng::new(42);
    let prompts: Vec<Vec<u16>> = (0..n_requests)
        .map(|_| {
            let len = 8 + rng.below(17);
            let off = rng.below(eval_toks.len() - len - 1);
            eval_toks[off..off + len].to_vec()
        })
        .collect();

    // phase 1: concurrent streaming clients
    println!("[e2e] submitting {n_requests} concurrent streaming requests...");
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for p in prompts {
        joins.push(std::thread::spawn(move || -> Result<(f64, f64, usize)> {
            let c = Client::connect(port)?;
            let h = c.submit(&GenerationParams::new(p).max_new(max_new))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let out = h.wait()?;
            Ok((out.stats.ttft_ms, out.stats.tokens_per_sec(),
                out.tokens.len()))
        }));
    }
    let mut ttfts = Vec::new();
    let mut tps = Vec::new();
    let mut total_tokens = 0usize;
    for j in joins {
        let (ttft, t, n) = j.join().unwrap()?;
        ttfts.push(ttft);
        tps.push(t);
        total_tokens += n;
    }
    let wall = t0.elapsed().as_secs_f64();

    // phase 2: interleaved token frames on ONE connection + mid-flight
    // cancellation — the acceptance scenario for the event protocol
    println!("[e2e] interleave + cancel on a single connection...");
    let interleave = run_interleave_cancel(port, eval_toks)?;

    let mut stats_client = Client::connect(port)?;
    let stats = stats_client.stats()?;
    handle.shutdown();

    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = ttfts[ttfts.len() / 2];
    let p95 = ttfts[(ttfts.len() - 1) * 95 / 100];
    let agg_tps = total_tokens as f64 / wall;
    let cache_b = stats.get("peak_cache_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let cache_fp16 = stats.get("peak_cache_fp16_bytes").and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    let saving = cache_fp16 / cache_b.max(1.0);
    let pool_after = stats.get("pool_pages_in_use").and_then(|v| v.as_f64())
        .unwrap_or(-1.0);
    // with the shared prefix cache on (the default), drained engines may
    // still pin donated prompt pages — but nothing beyond them
    let prefix_pinned = stats.get("prefix_pages_pinned")
        .and_then(|v| v.as_f64()).unwrap_or(0.0);
    assert_eq!(pool_after, prefix_pinned,
               "KV pages leaked after all requests drained \
                (in use {pool_after}, prefix-cache pinned {prefix_pinned})");

    // accuracy of the served model vs baseline
    println!("[e2e] measuring served-model perplexity vs f32 baseline...");
    let windows = 8;
    let r_int4 = art.runner(QuantSpec::quarot(4), None)?;
    let ppl_int4 = eval::perplexity(&r_int4, eval_toks, windows)?;
    drop(r_int4);
    let r_fp = art.runner(QuantSpec::fp16_baseline(), None)?;
    let ppl_fp = eval::perplexity(&r_fp, eval_toks, windows)?;

    let mut t = Table::new(
        &format!("E2E serving — {model}, QuaRot W4A4KV4, {n_requests} requests"),
        &["metric", "value"]);
    t.row(vec!["requests completed".into(), format!("{n_requests}")]);
    t.row(vec!["total generated tokens".into(), format!("{total_tokens}")]);
    t.row(vec!["wall time (s)".into(), format!("{wall:.2}")]);
    t.row(vec!["aggregate throughput (tok/s)".into(), format!("{agg_tps:.1}")]);
    t.row(vec!["median TTFT (ms)".into(), format!("{med:.1}")]);
    t.row(vec!["p95 TTFT (ms)".into(), format!("{p95:.1}")]);
    t.row(vec!["mean per-req decode tok/s".into(),
               format!("{:.1}", tps.iter().sum::<f64>() / tps.len() as f64)]);
    t.row(vec!["interleave/cancel check".into(), interleave]);
    t.row(vec!["pool pages after drain".into(), format!("{pool_after:.0}")]);
    t.row(vec!["peak KV cache (packed B)".into(), format!("{cache_b:.0}")]);
    t.row(vec!["peak KV cache (fp16-equiv B)".into(), format!("{cache_fp16:.0}")]);
    t.row(vec!["KV memory saving ×".into(), format!("{saving:.2}")]);
    t.row(vec!["ppl INT4 (served)".into(), format!("{ppl_int4:.3}")]);
    t.row(vec!["ppl f32 baseline".into(), format!("{ppl_fp:.3}")]);
    record("e2e_serving", &t.render())?;
    Ok(())
}

/// Two requests on one connection; request B is cancelled after its first
/// few token frames.  Asserts both streams terminate in exactly one
/// terminal event with the right reasons.
fn run_interleave_cancel(port: u16, eval_toks: &[u16]) -> Result<String> {
    let c = Client::connect(port)?;
    let ha = c.submit(&GenerationParams::new(eval_toks[..8].to_vec()).max_new(48))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    // B gets a budget ~190 ticks long and is cancelled at its first token
    // frame, so the cancel cannot lose the race to natural completion
    let hb = c.submit(&GenerationParams::new(eval_toks[40..48].to_vec()).max_new(190))
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // pull B until its first token streams, then cancel it mid-generation
    let mut b_tokens = 0usize;
    let mut b_terminals = 0usize;
    let mut b_reason = None;
    while let Some(ev) = hb.next_event()? {
        match ev {
            GenerationEvent::Token { .. } => {
                b_tokens += 1;
                if b_tokens == 1 {
                    hb.cancel()?;
                }
            }
            GenerationEvent::Finished { reason, .. } => {
                b_terminals += 1;
                b_reason = Some(reason);
            }
            GenerationEvent::Failed { .. } => b_terminals += 1,
            _ => {}
        }
    }
    // A must still run to completion, untouched by B's cancellation
    let out_a = ha.wait()?;
    assert_eq!(b_terminals, 1, "request B must see exactly one terminal event");
    assert_eq!(b_reason, Some(FinishReason::Cancelled));
    assert!(b_tokens < 190, "cancel must land mid-generation");
    assert!(!out_a.tokens.is_empty());
    assert!(matches!(out_a.reason,
                     FinishReason::MaxTokens | FinishReason::CacheFull),
            "A must run to its natural finish, got {}", out_a.reason);
    Ok(format!("ok (A: {} tokens {}, B: cancelled after {} tokens)",
               out_a.tokens.len(), out_a.reason, b_tokens))
}
