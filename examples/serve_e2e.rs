//! End-to-end serving validation (the required full-system driver).
//!
//! Boots the complete stack — AOT-compiled QuaRot-INT4 graphs, paged
//! quantized KV cache, continuous batcher, TCP server — submits a batch of
//! concurrent generation requests through the network front-end, and
//! reports per-request latency, aggregate throughput, KV-cache memory vs
//! the FP16-equivalent, and the held-out perplexity of the served INT4
//! model next to the f32 baseline.  Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example serve_e2e [-- --requests 12]`.

use anyhow::Result;

use quarot::bench_support::{record, Artifacts};
use quarot::coordinator::batcher::GenerationEngine;
use quarot::coordinator::runner::QuantSpec;
use quarot::eval;
use quarot::server::{serve, Client};
use quarot::util::bench::Table;
use quarot::util::cli::Args;
use quarot::util::prng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.str_or("model", "tiny-mha");
    let n_requests = args.usize_or("requests", 10);
    let max_new = args.usize_or("max-new", 24);

    println!("[e2e] starting server with QuaRot-INT4 engine ({model})...");
    let m2 = model.clone();
    let handle = serve(
        move || {
            let art = Artifacts::load(&m2)?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 2048, 7))
        },
        0,
    )?;
    let port = handle.port;

    // build prompts from held-out data
    let art = Artifacts::load(&model)?;
    let eval_toks = art.corpus.split("eval")?;
    let mut rng = Rng::new(42);
    let prompts: Vec<Vec<u16>> = (0..n_requests)
        .map(|_| {
            let len = 8 + rng.below(17);
            let off = rng.below(eval_toks.len() - len - 1);
            eval_toks[off..off + len].to_vec()
        })
        .collect();

    // concurrent clients
    println!("[e2e] submitting {n_requests} concurrent requests...");
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for p in prompts {
        joins.push(std::thread::spawn(move || -> Result<(f64, f64, usize)> {
            let mut c = Client::connect(port)?;
            let resp = c.generate(&p, max_new)?;
            let err = resp.get("error").and_then(|e| e.as_str());
            if let Some(e) = err {
                anyhow::bail!("server error: {e}");
            }
            Ok((
                resp.get("ttft_ms").and_then(|v| v.as_f64()).unwrap_or(-1.0),
                resp.get("tokens_per_sec").and_then(|v| v.as_f64()).unwrap_or(0.0),
                resp.get("tokens").and_then(|v| v.as_arr()).map(|a| a.len()).unwrap_or(0),
            ))
        }));
    }
    let mut ttfts = Vec::new();
    let mut tps = Vec::new();
    let mut total_tokens = 0usize;
    for j in joins {
        let (ttft, t, n) = j.join().unwrap()?;
        ttfts.push(ttft);
        tps.push(t);
        total_tokens += n;
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut stats_client = Client::connect(port)?;
    let stats = stats_client.stats()?;
    handle.shutdown();

    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = ttfts[ttfts.len() / 2];
    let p95 = ttfts[(ttfts.len() - 1) * 95 / 100];
    let agg_tps = total_tokens as f64 / wall;
    let cache_b = stats.get("peak_cache_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let cache_fp16 = stats.get("peak_cache_fp16_bytes").and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    let saving = cache_fp16 / cache_b.max(1.0);

    // accuracy of the served model vs baseline
    println!("[e2e] measuring served-model perplexity vs f32 baseline...");
    let windows = 8;
    let r_int4 = art.runner(QuantSpec::quarot(4), None)?;
    let ppl_int4 = eval::perplexity(&r_int4, eval_toks, windows)?;
    drop(r_int4);
    let r_fp = art.runner(QuantSpec::fp16_baseline(), None)?;
    let ppl_fp = eval::perplexity(&r_fp, eval_toks, windows)?;

    let mut t = Table::new(
        &format!("E2E serving — {model}, QuaRot W4A4KV4, {n_requests} requests"),
        &["metric", "value"]);
    t.row(vec!["requests completed".into(), format!("{n_requests}")]);
    t.row(vec!["total generated tokens".into(), format!("{total_tokens}")]);
    t.row(vec!["wall time (s)".into(), format!("{wall:.2}")]);
    t.row(vec!["aggregate throughput (tok/s)".into(), format!("{agg_tps:.1}")]);
    t.row(vec!["median TTFT (ms)".into(), format!("{med:.1}")]);
    t.row(vec!["p95 TTFT (ms)".into(), format!("{p95:.1}")]);
    t.row(vec!["mean per-req decode tok/s".into(),
               format!("{:.1}", tps.iter().sum::<f64>() / tps.len() as f64)]);
    t.row(vec!["peak KV cache (packed B)".into(), format!("{cache_b:.0}")]);
    t.row(vec!["peak KV cache (fp16-equiv B)".into(), format!("{cache_fp16:.0}")]);
    t.row(vec!["KV memory saving ×".into(), format!("{saving:.2}")]);
    t.row(vec!["ppl INT4 (served)".into(), format!("{ppl_int4:.3}")]);
    t.row(vec!["ppl f32 baseline".into(), format!("{ppl_fp:.3}")]);
    record("e2e_serving", &t.render())?;
    Ok(())
}
