//! Quickstart: load the QuaRot-INT4 model, stream a generation through
//! the unified inference API, and compare against the FP16 baseline —
//! the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use anyhow::Result;

use quarot::api::{GenerationEvent, GenerationParams, LocalSession, SessionConfig};
use quarot::bench_support::Artifacts;
use quarot::coordinator::batcher::GenerationEngine;
use quarot::coordinator::runner::QuantSpec;

fn main() -> Result<()> {
    let art = match Artifacts::load("tiny-mha") {
        Ok(a) => a,
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            return Ok(());
        }
    };

    // A prompt from the held-out corpus (token ids — the synthetic language
    // has no detokenizer; see DESIGN.md §1).
    let eval = art.corpus.split("eval")?;
    let prompt: Vec<u16> = eval[..12].to_vec();

    for (label, spec) in [
        ("FP16 baseline", QuantSpec::fp16_baseline()),
        ("QuaRot W4A4KV4", QuantSpec::quarot(4)),
    ] {
        println!("== {label} ==");
        let runner = art.runner(spec, None)?;
        let session = LocalSession::new(GenerationEngine::new(runner, 512, 7),
                                        SessionConfig::default());
        let handle = session
            .submit(GenerationParams::new(prompt.clone()).max_new(24))
            .map_err(|e| anyhow::anyhow!("{e}"))?;

        // consume the event stream: tokens arrive one by one
        println!("prompt  {prompt:?}");
        print!("output  ");
        let mut done = None;
        while let Some(ev) = handle.next_event()? {
            match ev {
                GenerationEvent::Token { token, .. } => print!("{token} "),
                GenerationEvent::Finished { reason, stats } => {
                    done = Some((reason, stats));
                }
                GenerationEvent::Failed { error } => {
                    anyhow::bail!("generation failed: {error}");
                }
                _ => {}
            }
        }
        println!();
        let (reason, stats) = done.expect("stream must terminate");
        let engine_stats = session.stats();
        println!("finish {reason} | ttft {:.1} ms | {:.1} tok/s | \
                  peak cache {} B (fp16-equiv {} B)",
                 stats.ttft_ms, stats.tokens_per_sec(),
                 engine_stats.peak_cache_bytes,
                 engine_stats.peak_cache_fp16_bytes);
        println!();
    }
    Ok(())
}
