//! Quickstart: load the QuaRot-INT4 model, generate a few sequences, and
//! compare against the FP16 baseline — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use anyhow::Result;

use quarot::bench_support::Artifacts;
use quarot::coordinator::batcher::{GenerationEngine, Request};
use quarot::coordinator::runner::QuantSpec;
use quarot::coordinator::sampler::Sampling;

fn main() -> Result<()> {
    let art = Artifacts::load("tiny-mha")?;

    // A prompt from the held-out corpus (token ids — the synthetic language
    // has no detokenizer; see DESIGN.md §1).
    let eval = art.corpus.split("eval")?;
    let prompt: Vec<u16> = eval[..12].to_vec();

    for (label, spec) in [
        ("FP16 baseline", QuantSpec::fp16_baseline()),
        ("QuaRot W4A4KV4", QuantSpec::quarot(4)),
    ] {
        println!("== {label} ==");
        let runner = art.runner(spec, None)?;
        let mut engine = GenerationEngine::new(runner, 512, 7);
        engine.submit(Request {
            id: 0,
            prompt: prompt.clone(),
            max_new_tokens: 24,
            sampling: Sampling::Greedy,
            stop_token: None,
        });
        for c in engine.run_to_completion()? {
            println!("prompt  {:?}", prompt);
            println!("output  {:?}", c.tokens);
            println!("ttft {:.1} ms | {:.1} tok/s | peak cache {} B \
                      (fp16-equiv {} B)",
                     c.ttft_ms,
                     c.tokens.len() as f64 / (c.decode_ms / 1e3).max(1e-9),
                     engine.stats.peak_cache_bytes,
                     engine.stats.peak_cache_fp16_bytes);
        }
        println!();
    }
    Ok(())
}
